//! Secure aggregation with mask sparsification — the paper's second
//! contribution (§3.2, Algorithm 2), plus every cryptographic substrate
//! it needs, built in-repo:
//!
//! * [`bignum`] — fixed-limb big unsigned integers with modpow
//! * [`dh`] — finite-field Diffie-Hellman (RFC 3526 MODP groups)
//! * [`kdf`] — HKDF-SHA256 shared-secret → mask-seed derivation
//! * [`mask`] — pairwise additive masks expanded by ChaCha20
//! * [`sparse_mask`] — the zero-local-value mask matrix (Eq. 3-5)
//! * [`neighborhood`] — seeded k-regular mask topologies (the
//!   sparsified-secagg graph replacing the complete pair graph)
//! * [`shamir`] — Shamir secret sharing (Bonawitz-style dropout
//!   recovery, the paper's SA baseline substrate)
//! * [`rekey`] — per-round neighborhood-local Shamir re-keying of DH
//!   exponents (O(n·k) share material; secrets only at current
//!   neighbors)
//! * [`protocol`] — client/server round protocol gluing it together

pub mod bignum;
pub mod dh;
pub mod kdf;
pub mod mask;
pub mod neighborhood;
pub mod protocol;
pub mod rekey;
pub mod shamir;
pub mod sparse_mask;

pub use dh::{DhKeyPair, DhParams};
pub use mask::PairwiseMasker;
pub use neighborhood::Neighborhood;
pub use protocol::{recover_pair_keys, recover_pair_keys_in, SecAggClient, SecAggConfig, SecAggServer};
pub use rekey::{recover_pair_keys_rekeyed, RekeyRegistry, RekeyStats};
pub use sparse_mask::{
    mask_sparsify, mask_sparsify_into, CaseCensus, MaskScratch, MaskSparsifyConfig, MaskedUpdate,
};
