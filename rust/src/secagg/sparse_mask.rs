//! Mask-sparsified secure update construction — Algorithm 2's client
//! core (Eq. 3-5):
//!
//! ```text
//! mask_top[j] = |G[j]| ≥ δ_topk          (Eq. 3: Top-k gradient mask)
//! mask_e[j]   = mask_r[j] if mask_r[j] < σ else 0   (zero-local-value)
//! mask_t[j]   = mask_top[j] ∨ (mask_e[j] ≠ 0)       (transmission mask)
//! G_sparse    = encode((G + mask_e) ⊙ mask_t)       (Eq. 5)
//! G_residual  = G ⊙ ¬mask_t             (Alg. 2 line 17)
//! ```
//!
//! The transmission mask is the key invariant: a position is sent iff
//! the gradient is Top-k there **or** the pair mask is non-zero there.
//! Because both sides of a pair keep identical mask positions, every
//! transmitted mask value meets its opposite-signed twin at the server
//! and cancels — condition 1 of §3.2. Positions sent for Top-k with a
//! zero mask are the §4 case-1 exposure, which the paper accepts and
//! we census in [`CaseCensus`].

use crate::sparse::codec::SparseVec;
use crate::util::pool::ThreadPool;

use super::mask::{MaskRange, PairwiseMasker};

/// Configuration for the masked sparsification step.
#[derive(Clone, Copy, Debug)]
pub struct MaskSparsifyConfig {
    pub range: MaskRange,
    /// The paper's `k` in Eq. 4 (random mask ratio numerator).
    pub mask_ratio_k: f64,
    /// The paper's `x` (number of participants this round).
    pub participants: usize,
}

impl MaskSparsifyConfig {
    pub fn sigma(&self) -> f32 {
        self.range.sigma(self.mask_ratio_k, self.participants)
    }

    /// Expected fraction of positions carrying a non-zero pair mask
    /// from ONE pair: `k/x` (Eq. 4).
    pub fn mask_keep_fraction(&self) -> f64 {
        (self.mask_ratio_k / self.participants as f64).clamp(0.0, 1.0)
    }
}

/// §4 case census over one masked update: positions by
/// (gradient-sent, mask-nonzero). `case1` = grad ∧ ¬mask (raw value
/// exposed), `case2` = ¬grad ∧ mask (pure mask noise transmitted),
/// `case3` = grad ∧ mask (fully protected), `silent` = neither.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CaseCensus {
    pub case1_grad_only: usize,
    pub case2_mask_only: usize,
    pub case3_both: usize,
    pub silent: usize,
}

impl CaseCensus {
    pub fn transmitted(&self) -> usize {
        self.case1_grad_only + self.case2_mask_only + self.case3_both
    }

    /// Fraction of transmitted positions that carry an unprotected raw
    /// gradient value (§4 case 1).
    pub fn exposure_rate(&self) -> f64 {
        let t = self.transmitted();
        if t == 0 {
            0.0
        } else {
            self.case1_grad_only as f64 / t as f64
        }
    }
}

/// Output of the masked sparsification.
#[derive(Clone, Debug, Default)]
pub struct MaskedUpdate {
    /// The wire payload: `(G + mask_e) ⊙ mask_t`, sparse.
    pub payload: SparseVec,
    /// `G ⊙ ¬mask_t`, accumulated locally.
    pub residual: Vec<f32>,
    pub census: CaseCensus,
}

/// Reusable scratch for [`mask_sparsify_into`]: the combined-mask
/// accumulator and its nonzero map (both model-sized). Held in the
/// per-worker `ClientWorkspace` so steady-state rounds never allocate
/// them.
#[derive(Debug, Default)]
pub struct MaskScratch {
    acc: Vec<f32>,
    nz: Vec<bool>,
}

/// The masked-sparsify sweep (rust twin of the pallas `masked_agg` /
/// `sparsify` kernels on the client side).
///
/// * `g` — the update vector after residual fold-in
/// * `grad_keep` — Top-k decision per position (from
///   [`crate::sparse::thgs::thgs_sparsify`]'s nonzero pattern or a flat
///   threshold)
/// * `masker`/`round` — pairwise mask source
pub fn mask_sparsify(
    g: &[f32],
    grad_keep: &[bool],
    masker: &PairwiseMasker,
    round: u64,
    cfg: &MaskSparsifyConfig,
) -> MaskedUpdate {
    let mut scratch = MaskScratch::default();
    let mut out = MaskedUpdate::default();
    mask_sparsify_into(g, grad_keep, masker, round, cfg, &mut scratch, &mut out);
    out
}

/// [`mask_sparsify`] into caller-owned scratch + output buffers —
/// the zero-allocation hot path (identical results; the allocating
/// wrapper above just feeds it fresh buffers).
pub fn mask_sparsify_into(
    g: &[f32],
    grad_keep: &[bool],
    masker: &PairwiseMasker,
    round: u64,
    cfg: &MaskSparsifyConfig,
    scratch: &mut MaskScratch,
    out: &mut MaskedUpdate,
) {
    assert_eq!(g.len(), grad_keep.len(), "grad_keep length mismatch");
    let sigma = cfg.sigma();
    masker.sparse_combined_mask_into(round, g.len(), sigma, &mut scratch.acc, &mut scratch.nz);
    split_with_masks(g, grad_keep, scratch, out);
}

/// [`mask_sparsify_into`] with the pair-mask stream generation fanned
/// out over `pool` (see
/// [`PairwiseMasker::sparse_combined_mask_pooled_into`] for the
/// reduction-order contract). Bitwise identical to the serial path.
pub fn mask_sparsify_pooled_into(
    g: &[f32],
    grad_keep: &[bool],
    masker: &PairwiseMasker,
    round: u64,
    cfg: &MaskSparsifyConfig,
    pool: &ThreadPool,
    scratch: &mut MaskScratch,
    out: &mut MaskedUpdate,
) {
    assert_eq!(g.len(), grad_keep.len(), "grad_keep length mismatch");
    let sigma = cfg.sigma();
    masker.sparse_combined_mask_pooled_into(
        pool,
        round,
        g.len(),
        sigma,
        &mut scratch.acc,
        &mut scratch.nz,
    );
    split_with_masks(g, grad_keep, scratch, out);
}

/// The Eq. 3-5 split sweep shared by the serial and pooled entry
/// points: consumes the combined mask in `scratch` and writes the
/// payload / residual / census into `out`.
fn split_with_masks(g: &[f32], grad_keep: &[bool], scratch: &MaskScratch, out: &mut MaskedUpdate) {
    let (mask_e, mask_nz) = (&scratch.acc, &scratch.nz);

    let mut census = CaseCensus::default();
    out.payload.n = g.len() as u32;
    let indices = &mut out.payload.indices;
    let values = &mut out.payload.values;
    indices.clear();
    values.clear();
    out.residual.clear();
    out.residual.resize(g.len(), 0.0);
    let residual = &mut out.residual;

    for j in 0..g.len() {
        match (grad_keep[j], mask_nz[j]) {
            (true, false) => {
                census.case1_grad_only += 1;
                indices.push(j as u32);
                values.push(g[j]); // mask_e is zero here
            }
            (false, true) => {
                census.case2_mask_only += 1;
                indices.push(j as u32);
                // the gradient component rides along under the mask —
                // it is NOT lost to the residual (it ships, protected)
                values.push(g[j] + mask_e[j]);
            }
            (true, true) => {
                census.case3_both += 1;
                indices.push(j as u32);
                values.push(g[j] + mask_e[j]);
            }
            (false, false) => {
                census.silent += 1;
                residual[j] = g[j];
            }
        }
    }
    out.census = census;
}

/// Server side: sum masked sparse payloads; pair masks cancel, leaving
/// `Σ_u G_u ⊙ mask_t_u`. Returns the dense sum.
pub fn aggregate_masked(n: usize, payloads: &[SparseVec]) -> Vec<f32> {
    let mut acc = vec![0f32; n];
    for p in payloads {
        assert_eq!(p.n as usize, n, "payload length mismatch");
        p.add_into(&mut acc);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::secagg::mask::MaskRange;
    use crate::util::rng::Rng;

    /// Build an all-pairs fleet with deterministic secrets.
    fn fleet(n: u32) -> Vec<PairwiseMasker> {
        let secret = |a: u32, b: u32| -> Vec<u8> {
            let (lo, hi) = if a < b { (a, b) } else { (b, a) };
            format!("s{lo}:{hi}").into_bytes()
        };
        (0..n)
            .map(|id| {
                let peers = (0..n)
                    .filter(|&p| p != id)
                    .map(|p| (p, secret(id, p)))
                    .collect();
                PairwiseMasker::new(id, peers, MaskRange::default())
            })
            .collect()
    }

    fn cfg(x: usize) -> MaskSparsifyConfig {
        MaskSparsifyConfig {
            range: MaskRange::default(),
            mask_ratio_k: 1.0,
            participants: x,
        }
    }

    #[test]
    fn masks_cancel_in_aggregate() {
        let n = 4000;
        let x = 4;
        let f = fleet(x as u32);
        let mut rng = Rng::new(1);
        let mut true_sum = vec![0f64; n];
        let mut payloads = Vec::new();
        let mut sent_any = vec![false; n];

        for c in &f {
            let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.1)).collect();
            // flat top-5% keep pattern
            let delta = crate::sparse::topk::threshold_for_topk_abs(&g, n / 20);
            let keep: Vec<bool> = g.iter().map(|v| v.abs() > delta).collect();
            let out = mask_sparsify(&g, &keep, c, 11, &cfg(x));
            // mass conservation: payload(unmasked part) + residual == g
            for j in 0..n {
                let shipped = out.payload.to_dense()[j];
                let _ = shipped;
                // (checked in aggregate below; per-client values are masked)
                true_sum[j] += (g[j] - out.residual[j]) as f64;
            }
            for &i in &out.payload.indices {
                sent_any[i as usize] = true;
            }
            payloads.push(out.payload);
        }

        let agg = aggregate_masked(n, &payloads);
        for j in 0..n {
            assert!(
                (agg[j] as f64 - true_sum[j]).abs() < 2e-3,
                "mask residue at {j}: {} vs {}",
                agg[j],
                true_sum[j]
            );
        }
        assert!(sent_any.iter().any(|&b| b));
    }

    #[test]
    fn pooled_mask_sparsify_bitwise_matches_serial() {
        let n = 2500;
        for x in [2u32, 3, 8, 17] {
            let f = fleet(x);
            let mut rng = Rng::new(7 + x as u64);
            let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.5)).collect();
            let delta = crate::sparse::topk::threshold_for_topk_abs(&g, n / 50);
            let keep: Vec<bool> = g.iter().map(|v| v.abs() > delta).collect();
            let pool = ThreadPool::new(3);
            let serial = mask_sparsify(&g, &keep, &f[0], 4, &cfg(x as usize));
            let mut scratch = MaskScratch::default();
            let mut pooled = MaskedUpdate::default();
            mask_sparsify_pooled_into(
                &g,
                &keep,
                &f[0],
                4,
                &cfg(x as usize),
                &pool,
                &mut scratch,
                &mut pooled,
            );
            assert_eq!(serial.payload.indices, pooled.payload.indices, "x={x}");
            assert!(
                serial
                    .payload
                    .values
                    .iter()
                    .zip(&pooled.payload.values)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "x={x}: pooled payload values diverged"
            );
            assert_eq!(serial.census, pooled.census, "x={x}");
            assert!(
                serial
                    .residual
                    .iter()
                    .zip(&pooled.residual)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "x={x}: pooled residual diverged"
            );
        }
    }

    #[test]
    fn census_partitions_all_positions() {
        let n = 1000;
        let f = fleet(3);
        let mut rng = Rng::new(2);
        let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
        let keep: Vec<bool> = (0..n).map(|i| i % 10 == 0).collect();
        let out = mask_sparsify(&g, &keep, &f[0], 5, &cfg(3));
        let c = out.census;
        assert_eq!(c.case1_grad_only + c.case2_mask_only + c.case3_both + c.silent, n);
        assert_eq!(out.payload.nnz(), c.transmitted());
    }

    #[test]
    fn residual_holds_only_silent_positions() {
        let n = 500;
        let f = fleet(2);
        let mut rng = Rng::new(3);
        let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
        let keep = vec![false; n];
        let out = mask_sparsify(&g, &keep, &f[0], 1, &cfg(2));
        for j in 0..n {
            let sent = out.payload.indices.binary_search(&(j as u32)).is_ok();
            if sent {
                assert_eq!(out.residual[j], 0.0);
            } else {
                assert_eq!(out.residual[j], g[j]);
            }
        }
    }

    #[test]
    fn mask_only_positions_carry_gradient_under_mask() {
        // the gradient at mask-only positions ships (protected), so it
        // must NOT also sit in the residual
        let n = 200;
        let f = fleet(2);
        let g = vec![0.5f32; n];
        let keep = vec![false; n];
        let out = mask_sparsify(&g, &keep, &f[0], 2, &cfg(2));
        for (i, &idx) in out.payload.indices.iter().enumerate() {
            let j = idx as usize;
            assert_eq!(out.residual[j], 0.0);
            // value = g + mask ≠ g (mask almost surely nonzero)
            assert_ne!(out.payload.values[i], g[j]);
        }
    }

    #[test]
    fn sigma_zero_ratio_degenerates_to_plain_sparse() {
        let n = 300;
        let f = fleet(2);
        let mut rng = Rng::new(4);
        let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
        let keep: Vec<bool> = g.iter().map(|v| v.abs() > 1.0).collect();
        let c = MaskSparsifyConfig {
            range: MaskRange::default(),
            mask_ratio_k: 0.0, // σ = p → nothing below it → no masks
            participants: 2,
        };
        let out = mask_sparsify(&g, &keep, &f[0], 3, &c);
        assert_eq!(out.census.case2_mask_only, 0);
        assert_eq!(out.census.case3_both, 0);
        // payload is exactly the raw kept gradients
        for (i, &idx) in out.payload.indices.iter().enumerate() {
            assert_eq!(out.payload.values[i], g[idx as usize]);
        }
    }

    #[test]
    fn exposure_rate_drops_with_mask_ratio() {
        let n = 20_000;
        let f = fleet(2);
        let mut rng = Rng::new(5);
        let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
        let delta = crate::sparse::topk::threshold_for_topk_abs(&g, n / 100);
        let keep: Vec<bool> = g.iter().map(|v| v.abs() > delta).collect();

        let mut rates = Vec::new();
        for k in [0.2f64, 1.0, 1.8] {
            let c = MaskSparsifyConfig {
                range: MaskRange::default(),
                mask_ratio_k: k,
                participants: 2,
            };
            rates.push(mask_sparsify(&g, &keep, &f[0], 4, &c).census.exposure_rate());
        }
        assert!(rates[0] > rates[1] && rates[1] > rates[2], "rates={rates:?}");
    }
}
