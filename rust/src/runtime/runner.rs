//! Typed model/kernel execution on top of the executor pool.
//!
//! `ModelRunner` knows a model's manifest entry: it slices the flat
//! [`ParamVector`] into per-tensor literals, appends the batch, runs
//! the grad/eval artifact, and re-flattens the outputs.

use std::path::PathBuf;
use anyhow::{anyhow, Result};

use crate::models::manifest::{Manifest, ModelMeta};
use crate::models::params::ParamVector;

use super::executor::{ExecutorHandle, ExecutorPool, Tensor};

/// Grad/eval execution for one model.
#[derive(Clone)]
pub struct ModelRunner {
    pool: ExecutorHandle,
    pub meta: ModelMeta,
    grad_path: PathBuf,
    eval_path: PathBuf,
    pub train_batch: usize,
    pub eval_batch: usize,
}

impl ModelRunner {
    pub fn new(pool: &ExecutorPool, manifest: &Manifest, model: &str) -> Result<Self> {
        let meta = manifest
            .model(model)
            .ok_or_else(|| anyhow!("model {model} not in manifest"))?
            .clone();
        Ok(Self {
            grad_path: manifest.artifact_path(&meta.grad_artifact),
            eval_path: manifest.artifact_path(&meta.eval_artifact),
            train_batch: manifest.train_batch,
            eval_batch: manifest.eval_batch,
            pool: pool.handle(),
            meta,
        })
    }

    fn pack_params(&self, params: &ParamVector) -> Vec<Tensor> {
        self.meta
            .params
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let shape: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                Tensor::f32(shape, params.tensor(i).to_vec())
            })
            .collect()
    }

    fn input_shape(&self, batch: usize) -> Vec<i64> {
        std::iter::once(batch as i64)
            .chain(self.meta.input.iter().map(|&d| d as i64))
            .collect()
    }

    /// One grad step: returns `(loss, flat_grads)`.
    /// `x` is NHWC flattened (len = batch · prod(input)), `y` labels.
    pub fn grad(&self, params: &ParamVector, x: &[f32], y: &[i32]) -> Result<(f32, Vec<f32>)> {
        let b = self.train_batch;
        if y.len() != b {
            return Err(anyhow!("grad: expected batch {b}, got {}", y.len()));
        }
        let mut inputs = self.pack_params(params);
        inputs.push(Tensor::f32(self.input_shape(b), x.to_vec()));
        inputs.push(Tensor::i32(vec![b as i64], y.to_vec()));
        let out = self.pool.run(self.grad_path.clone(), inputs)?;
        if out.len() != 1 + self.meta.params.len() {
            return Err(anyhow!(
                "grad: expected {} outputs, got {}",
                1 + self.meta.params.len(),
                out.len()
            ));
        }
        let loss = out[0].scalar_f32()?;
        let mut grads = Vec::with_capacity(self.meta.total_params());
        for t in &out[1..] {
            grads.extend_from_slice(t.as_f32()?);
        }
        Ok((loss, grads))
    }

    /// Eval one shard: returns `(loss_sum, correct_count)`.
    pub fn eval_shard(&self, params: &ParamVector, x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let b = self.eval_batch;
        if y.len() != b {
            return Err(anyhow!("eval: expected batch {b}, got {}", y.len()));
        }
        let mut inputs = self.pack_params(params);
        inputs.push(Tensor::f32(self.input_shape(b), x.to_vec()));
        inputs.push(Tensor::i32(vec![b as i64], y.to_vec()));
        let out = self.pool.run(self.eval_path.clone(), inputs)?;
        Ok((out[0].scalar_f32()?, out[1].scalar_f32()?))
    }

    /// Evaluate over a whole dataset subset (loops eval-batch shards,
    /// truncating the tail so every shard is full). Returns
    /// `(mean_loss, accuracy)`.
    pub fn evaluate(
        &self,
        params: &ParamVector,
        data: &crate::data::Dataset,
        max_samples: usize,
    ) -> Result<(f64, f64)> {
        let b = self.eval_batch;
        let n = data.len().min(max_samples) / b * b;
        if n == 0 {
            return Err(anyhow!("eval set smaller than one shard ({b})"));
        }
        let mut loss_sum = 0f64;
        let mut correct = 0f64;
        for shard in 0..(n / b) {
            let idx: Vec<usize> = (shard * b..(shard + 1) * b).collect();
            let (x, y) = data.batch(&idx);
            let (l, c) = self.eval_shard(params, &x, &y)?;
            loss_sum += l as f64;
            correct += c as f64;
        }
        Ok((loss_sum / n as f64, correct / n as f64))
    }
}

/// Standalone pallas-kernel execution (parity tests + the optional
/// kernel-offload path).
#[derive(Clone)]
pub struct KernelRunner {
    pool: ExecutorHandle,
    sparsify: Vec<(usize, PathBuf)>,
    masked_agg: Vec<(usize, PathBuf)>,
}

impl KernelRunner {
    pub fn new(pool: &ExecutorPool, manifest: &Manifest) -> Self {
        Self {
            sparsify: manifest
                .sparsify_kernels
                .iter()
                .map(|(n, f)| (*n, manifest.artifact_path(f)))
                .collect(),
            masked_agg: manifest
                .masked_agg_kernels
                .iter()
                .map(|(n, f)| (*n, manifest.artifact_path(f)))
                .collect(),
            pool: pool.handle(),
        }
    }

    pub fn sparsify_sizes(&self) -> Vec<usize> {
        self.sparsify.iter().map(|(n, _)| *n).collect()
    }

    /// Run the pallas sparsify kernel of exactly size `n`.
    pub fn sparsify(&self, g: &[f32], thr: f32) -> Result<(Vec<f32>, Vec<f32>)> {
        let (_, path) = self
            .sparsify
            .iter()
            .find(|(n, _)| *n == g.len())
            .ok_or_else(|| anyhow!("no sparsify kernel for n={}", g.len()))?;
        let out = self.pool.run(
            path.clone(),
            vec![
                Tensor::f32(vec![g.len() as i64], g.to_vec()),
                Tensor::f32(vec![1], vec![thr]),
            ],
        )?;
        Ok((out[0].as_f32()?.to_vec(), out[1].as_f32()?.to_vec()))
    }

    /// Run the pallas masked-agg kernel of exactly size `n`.
    pub fn masked_agg(&self, acc: &[f32], contrib: &[f32], mask: &[f32]) -> Result<Vec<f32>> {
        let (_, path) = self
            .masked_agg
            .iter()
            .find(|(n, _)| *n == acc.len())
            .ok_or_else(|| anyhow!("no masked_agg kernel for n={}", acc.len()))?;
        let out = self.pool.run(
            path.clone(),
            vec![
                Tensor::f32(vec![acc.len() as i64], acc.to_vec()),
                Tensor::f32(vec![contrib.len() as i64], contrib.to_vec()),
                Tensor::f32(vec![mask.len() as i64], mask.to_vec()),
            ],
        )?;
        Ok(out[0].as_f32()?.to_vec())
    }
}
