//! PJRT-backed execution (feature `pjrt`): typed model/kernel wrappers
//! on top of the executor pool.
//!
//! [`PjrtBackend`] implements [`Backend`] by slicing the flat
//! [`ParamVector`] into per-tensor literals, appending the batch,
//! running the AOT grad/eval artifact, and re-flattening the outputs.
//! [`KernelRunner`] drives the standalone pallas kernels (parity tests
//! + the optional kernel-offload path).

use std::path::PathBuf;
use std::sync::Mutex;

use anyhow::{anyhow, Result};

use crate::models::manifest::{Manifest, ModelMeta};
use crate::models::params::ParamVector;

use super::backend::Backend;
use super::executor::{ExecutorHandle, ExecutorPool, Tensor};

/// Grad/eval execution for one model through the PJRT artifacts.
///
/// Owns its executor pool; the submission handle sits behind a mutex
/// because `mpsc::Sender` is not `Sync` (the lock covers only the
/// enqueue, not the compute).
pub struct PjrtBackend {
    /// MUST be declared (and therefore dropped) before `_pool`: the
    /// pool's Drop joins its workers, which only exit once every
    /// `Sender` clone — including this handle's — is gone.
    handle: Mutex<ExecutorHandle>,
    _pool: Mutex<ExecutorPool>,
    meta: ModelMeta,
    grad_path: PathBuf,
    eval_path: PathBuf,
}

impl PjrtBackend {
    /// Spawn `workers` executor threads for this model's artifacts.
    /// (PJRT client creation is lazy; its errors surface per job.)
    pub fn new(manifest: &Manifest, meta: &ModelMeta, workers: usize) -> Self {
        let pool = ExecutorPool::new(workers);
        Self {
            handle: Mutex::new(pool.handle()),
            grad_path: manifest.artifact_path(&meta.grad_artifact),
            eval_path: manifest.artifact_path(&meta.eval_artifact),
            meta: meta.clone(),
            _pool: Mutex::new(pool),
        }
    }

    fn submit(&self, artifact: PathBuf, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let rx = self.handle.lock().unwrap().run_async(artifact, inputs)?;
        rx.recv().map_err(|_| anyhow!("executor worker died"))?
    }

    fn pack_params(&self, params: &ParamVector) -> Vec<Tensor> {
        self.meta
            .params
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let shape: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                Tensor::f32(shape, params.tensor(i).to_vec())
            })
            .collect()
    }

    fn input_shape(&self, batch: usize) -> Vec<i64> {
        std::iter::once(batch as i64)
            .chain(self.meta.input.iter().map(|&d| d as i64))
            .collect()
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn grad(&self, params: &ParamVector, x: &[f32], y: &[i32]) -> Result<(f32, Vec<f32>)> {
        let b = y.len();
        let mut inputs = self.pack_params(params);
        inputs.push(Tensor::f32(self.input_shape(b), x.to_vec()));
        inputs.push(Tensor::i32(vec![b as i64], y.to_vec()));
        let out = self.submit(self.grad_path.clone(), inputs)?;
        if out.len() != 1 + self.meta.params.len() {
            return Err(anyhow!(
                "grad: expected {} outputs, got {}",
                1 + self.meta.params.len(),
                out.len()
            ));
        }
        let loss = out[0].scalar_f32()?;
        let mut grads = Vec::with_capacity(self.meta.total_params());
        for t in &out[1..] {
            grads.extend_from_slice(t.as_f32()?);
        }
        Ok((loss, grads))
    }

    fn eval_shard(&self, params: &ParamVector, x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let b = y.len();
        let mut inputs = self.pack_params(params);
        inputs.push(Tensor::f32(self.input_shape(b), x.to_vec()));
        inputs.push(Tensor::i32(vec![b as i64], y.to_vec()));
        let out = self.submit(self.eval_path.clone(), inputs)?;
        Ok((out[0].scalar_f32()?, out[1].scalar_f32()?))
    }
}

/// Standalone pallas-kernel execution (parity tests + the optional
/// kernel-offload path).
#[derive(Clone)]
pub struct KernelRunner {
    pool: ExecutorHandle,
    sparsify: Vec<(usize, PathBuf)>,
    masked_agg: Vec<(usize, PathBuf)>,
}

impl KernelRunner {
    pub fn new(pool: &ExecutorPool, manifest: &Manifest) -> Self {
        Self {
            sparsify: manifest
                .sparsify_kernels
                .iter()
                .map(|(n, f)| (*n, manifest.artifact_path(f)))
                .collect(),
            masked_agg: manifest
                .masked_agg_kernels
                .iter()
                .map(|(n, f)| (*n, manifest.artifact_path(f)))
                .collect(),
            pool: pool.handle(),
        }
    }

    pub fn sparsify_sizes(&self) -> Vec<usize> {
        self.sparsify.iter().map(|(n, _)| *n).collect()
    }

    /// Run the pallas sparsify kernel of exactly size `n`.
    pub fn sparsify(&self, g: &[f32], thr: f32) -> Result<(Vec<f32>, Vec<f32>)> {
        let (_, path) = self
            .sparsify
            .iter()
            .find(|(n, _)| *n == g.len())
            .ok_or_else(|| anyhow!("no sparsify kernel for n={}", g.len()))?;
        let out = self.pool.run(
            path.clone(),
            vec![
                Tensor::f32(vec![g.len() as i64], g.to_vec()),
                Tensor::f32(vec![1], vec![thr]),
            ],
        )?;
        Ok((out[0].as_f32()?.to_vec(), out[1].as_f32()?.to_vec()))
    }

    /// Run the pallas masked-agg kernel of exactly size `n`.
    pub fn masked_agg(&self, acc: &[f32], contrib: &[f32], mask: &[f32]) -> Result<Vec<f32>> {
        let (_, path) = self
            .masked_agg
            .iter()
            .find(|(n, _)| *n == acc.len())
            .ok_or_else(|| anyhow!("no masked_agg kernel for n={}", acc.len()))?;
        let out = self.pool.run(
            path.clone(),
            vec![
                Tensor::f32(vec![acc.len() as i64], acc.to_vec()),
                Tensor::f32(vec![contrib.len() as i64], contrib.to_vec()),
                Tensor::f32(vec![mask.len() as i64], mask.to_vec()),
            ],
        )?;
        Ok(out[0].as_f32()?.to_vec())
    }
}
