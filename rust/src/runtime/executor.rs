//! Executor pool: N dedicated threads, each with its own PJRT CPU
//! client and a lazily-compiled executable cache keyed by artifact
//! file. Jobs are message-passed; results come back on a per-job
//! channel. This is the only module that touches the `xla` crate.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

use anyhow::{anyhow, Context, Result};

/// A host-side tensor crossing the runtime boundary.
#[derive(Clone, Debug)]
pub enum Tensor {
    F32 { shape: Vec<i64>, data: Vec<f32> },
    I32 { shape: Vec<i64>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: Vec<i64>, data: Vec<f32>) -> Self {
        debug_assert_eq!(
            shape.iter().product::<i64>() as usize,
            data.len(),
            "shape/data mismatch"
        );
        Tensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<i64>, data: Vec<i32>) -> Self {
        debug_assert_eq!(
            shape.iter().product::<i64>() as usize,
            data.len(),
            "shape/data mismatch"
        );
        Tensor::I32 { shape, data }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            return Err(anyhow!("expected scalar, got {} elements", d.len()));
        }
        Ok(d[0])
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        // single-copy path: bytes straight into a shaped literal
        // (vec1().reshape() would copy twice; §Perf L3 iteration 1)
        match self {
            Tensor::F32 { shape, data } => {
                let dims: Vec<usize> = shape.iter().map(|&d| d as usize).collect();
                let bytes = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                Ok(xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    &dims,
                    bytes,
                )?)
            }
            Tensor::I32 { shape, data } => {
                let dims: Vec<usize> = shape.iter().map(|&d| d as usize).collect();
                let bytes = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                Ok(xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    &dims,
                    bytes,
                )?)
            }
        }
    }
}

struct Job {
    artifact: PathBuf,
    inputs: Vec<Tensor>,
    reply: mpsc::Sender<Result<Vec<Tensor>>>,
}

/// Pool of executor threads.
pub struct ExecutorPool {
    tx: mpsc::Sender<Job>,
    workers: Vec<thread::JoinHandle<()>>,
}

/// Cloneable, `Send` handle for submitting jobs from worker threads
/// (`mpsc::Sender` is `Send + Clone` but not `Sync`, so each thread
/// carries its own clone).
#[derive(Clone)]
pub struct ExecutorHandle {
    tx: mpsc::Sender<Job>,
}

impl ExecutorHandle {
    /// Execute `artifact` with `inputs`, blocking until done.
    pub fn run(&self, artifact: PathBuf, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Job { artifact, inputs, reply })
            .map_err(|_| anyhow!("executor pool shut down"))?;
        rx.recv().map_err(|_| anyhow!("executor worker died"))?
    }

    /// Fire a job and return the reply channel.
    pub fn run_async(
        &self,
        artifact: PathBuf,
        inputs: Vec<Tensor>,
    ) -> Result<mpsc::Receiver<Result<Vec<Tensor>>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Job { artifact, inputs, reply })
            .map_err(|_| anyhow!("executor pool shut down"))?;
        Ok(rx)
    }
}

impl ExecutorPool {
    /// Spawn `n` executor threads (each creates its own PJRT client on
    /// first use; creation errors surface per job).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let shared = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("fedsparse-exec-{i}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawn executor")
            })
            .collect();
        Self { tx, workers }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// A cloneable submission handle (for cross-thread use).
    pub fn handle(&self) -> ExecutorHandle {
        ExecutorHandle { tx: self.tx.clone() }
    }

    /// Execute `artifact` with `inputs`, blocking until done.
    pub fn run(&self, artifact: PathBuf, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Job { artifact, inputs, reply })
            .map_err(|_| anyhow!("executor pool shut down"))?;
        rx.recv().map_err(|_| anyhow!("executor worker died"))?
    }

    /// Fire a job and return the reply channel (overlap client work).
    pub fn run_async(
        &self,
        artifact: PathBuf,
        inputs: Vec<Tensor>,
    ) -> Result<mpsc::Receiver<Result<Vec<Tensor>>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Job { artifact, inputs, reply })
            .map_err(|_| anyhow!("executor pool shut down"))?;
        Ok(rx)
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        // closing the channel ends the workers
        let (dead_tx, _) = mpsc::channel();
        drop(std::mem::replace(&mut self.tx, dead_tx));
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(rx: Arc<Mutex<mpsc::Receiver<Job>>>) {
    // per-thread PJRT state (xla types are not Send)
    let mut client: Option<xla::PjRtClient> = None;
    let mut cache: HashMap<PathBuf, xla::PjRtLoadedExecutable> = HashMap::new();

    loop {
        let job = match rx.lock().unwrap().recv() {
            Ok(j) => j,
            Err(_) => return, // pool dropped
        };
        let result = execute_job(&mut client, &mut cache, &job);
        let _ = job.reply.send(result);
    }
}

fn execute_job(
    client: &mut Option<xla::PjRtClient>,
    cache: &mut HashMap<PathBuf, xla::PjRtLoadedExecutable>,
    job: &Job,
) -> Result<Vec<Tensor>> {
    if client.is_none() {
        *client = Some(xla::PjRtClient::cpu().context("create PJRT CPU client")?);
    }
    let c = client.as_ref().unwrap();

    if !cache.contains_key(&job.artifact) {
        let path = job
            .artifact
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = c.compile(&comp).with_context(|| format!("compile {path}"))?;
        cache.insert(job.artifact.clone(), exe);
    }
    let exe = cache.get(&job.artifact).unwrap();

    let literals: Vec<xla::Literal> = job
        .inputs
        .iter()
        .map(|t| t.to_literal())
        .collect::<Result<_>>()?;
    let result = exe.execute::<xla::Literal>(&literals)?;
    let out = result[0][0].to_literal_sync()?;
    // aot.py lowers with return_tuple=True → always a tuple
    let elems = out.to_tuple()?;
    elems
        .into_iter()
        .map(|lit| {
            let shape = lit.array_shape()?;
            let dims: Vec<i64> = shape.dims().to_vec();
            match shape.ty() {
                xla::ElementType::F32 => Ok(Tensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
                xla::ElementType::S32 => Ok(Tensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
                other => Err(anyhow!("unsupported output element type {other:?}")),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests need the AOT artifacts; they no-op when absent so
    /// `cargo test` stays green pre-`make artifacts` (integration tests
    /// cover the full path).
    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn sparsify_artifact_roundtrip() {
        let Some(dir) = artifacts_dir() else { return };
        let pool = ExecutorPool::new(1);
        let n = 1024;
        let g: Vec<f32> = (0..n).map(|i| (i as f32 / n as f32) - 0.5).collect();
        let out = pool
            .run(
                dir.join("sparsify_1024.hlo.txt"),
                vec![
                    Tensor::f32(vec![n as i64], g.clone()),
                    Tensor::f32(vec![1], vec![0.25]),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 2);
        let sparse = out[0].as_f32().unwrap();
        let residual = out[1].as_f32().unwrap();
        for i in 0..n {
            assert_eq!(sparse[i] + residual[i], g[i]);
            if g[i].abs() > 0.25 {
                assert_eq!(sparse[i], g[i]);
            } else {
                assert_eq!(sparse[i], 0.0);
            }
        }
    }

    #[test]
    fn masked_agg_artifact_roundtrip() {
        let Some(dir) = artifacts_dir() else { return };
        let pool = ExecutorPool::new(1);
        let n = 1024usize;
        let acc = vec![1.0f32; n];
        let contrib = vec![2.0f32; n];
        let mask: Vec<f32> = (0..n).map(|i| (i % 2) as f32).collect();
        let out = pool
            .run(
                dir.join("masked_agg_1024.hlo.txt"),
                vec![
                    Tensor::f32(vec![n as i64], acc),
                    Tensor::f32(vec![n as i64], contrib),
                    Tensor::f32(vec![n as i64], mask),
                ],
            )
            .unwrap();
        let res = out[0].as_f32().unwrap();
        for i in 0..n {
            assert_eq!(res[i], 1.0 + 2.0 * (i % 2) as f32);
        }
    }

    #[test]
    fn missing_artifact_errors_cleanly() {
        let pool = ExecutorPool::new(1);
        let err = pool
            .run(PathBuf::from("/nonexistent/foo.hlo.txt"), vec![])
            .unwrap_err();
        assert!(format!("{err:#}").contains("foo.hlo.txt"));
    }

    #[test]
    fn pool_parallel_jobs() {
        let Some(dir) = artifacts_dir() else { return };
        let pool = Arc::new(ExecutorPool::new(2));
        let mut rxs = Vec::new();
        for _ in 0..8 {
            let g = vec![0.5f32; 1024];
            let rx = pool
                .run_async(
                    dir.join("sparsify_1024.hlo.txt"),
                    vec![
                        Tensor::f32(vec![1024], g),
                        Tensor::f32(vec![1], vec![1.0]),
                    ],
                )
                .unwrap();
            rxs.push(rx);
        }
        for rx in rxs {
            let out = rx.recv().unwrap().unwrap();
            assert!(out[0].as_f32().unwrap().iter().all(|&v| v == 0.0));
        }
    }
}
