//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! exported once by `python -m compile.aot`) and executes them from the
//! rust round loop. Python never runs here.
//!
//! * [`executor`] — a pool of dedicated executor threads, each owning
//!   its own `PjRtClient` (the xla crate's client is `Rc`-based and not
//!   `Send`, so compute jobs are message-passed to the owning thread)
//! * [`runner`] — typed wrappers: `ModelRunner::{grad, eval}` pack the
//!   flat [`crate::models::ParamVector`] + batch into PJRT literals and
//!   parse the tuple outputs back
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.

pub mod executor;
pub mod runner;

pub use executor::{ExecutorHandle, ExecutorPool, Tensor};
pub use runner::{KernelRunner, ModelRunner};
