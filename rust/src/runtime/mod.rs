//! Model compute runtime — the [`Backend`] abstraction plus its two
//! implementations.
//!
//! * [`backend`] — the [`Backend`] trait, the [`BackendKind`]
//!   selector, and [`ModelRunner`], the coordinator-facing façade
//! * [`native`] — the default pure-Rust backend: MLP forward/grad/eval
//!   directly on flat [`crate::models::ParamVector`] slices; no
//!   Python, JAX, or PJRT artifacts required, fully deterministic
//! * [`executor`] / [`runner`] (feature `pjrt`) — the AOT-artifact
//!   path: `artifacts/*.hlo.txt` (exported once by
//!   `python -m compile.aot`) compiled and executed through the PJRT
//!   C API on a pool of dedicated executor threads (the xla crate's
//!   client is `Rc`-based and not `Send`, so compute jobs are
//!   message-passed to the owning thread)
//!
//! Backend selection (see [`BackendKind`]): `Auto` prefers PJRT when
//! the build has the feature and the artifacts exist, and falls back
//! to the native backend otherwise, so a clean checkout trains with
//! zero setup.

pub mod backend;
pub mod native;

#[cfg(feature = "pjrt")]
pub mod executor;
#[cfg(feature = "pjrt")]
pub mod runner;

pub use backend::{Backend, BackendKind, ModelRunner};
#[doc(hidden)]
pub use native::bench_dense_backward_input;
pub use native::{NativeBackend, Workspace};

#[cfg(feature = "pjrt")]
pub use executor::{ExecutorHandle, ExecutorPool, Tensor};
#[cfg(feature = "pjrt")]
pub use runner::{KernelRunner, PjrtBackend};
