//! Pure-Rust compute backend: forward / grad / eval for the MLP model
//! family, straight on flat [`ParamVector`] slices.
//!
//! The manifest's MLP models (`mnist_mlp`: 784→200→10, 159,010
//! params) are alternating `(weight [d_in, d_out], bias [d_out])`
//! pairs with ReLU between layers and softmax-cross-entropy at the
//! top — exactly what the AOT grad/eval artifacts compute. This
//! implementation reproduces that math with register-blocked kernels,
//! so the full federated round loop runs deterministically on any
//! machine with no Python, JAX, or PJRT artifacts.
//!
//! Layouts are row-major throughout: activations `[batch, d]`,
//! weights `[d_in, d_out]` (manifest order). Gradients come back as
//! one flat vector in manifest parameter order, like the PJRT path.
//!
//! ## Kernel shape & the bitwise-determinism constraint
//!
//! The kernels process [`ROW_BLOCK`] batch rows at once (each weight
//! row is loaded once per block instead of once per row), tile the
//! output dimension in [`OUT_TILE`]-wide strips whose accumulators
//! live on the stack, fuse ReLU into the forward store, and skip
//! all-zero input columns (image pixels and ReLU activations are
//! mostly zero). Crucially, every individual accumulator still
//! receives its additions in the ORIGINAL order — ascending `d_in`
//! (forward / dprev) or ascending batch row (weight grads), with the
//! same skip-if-zero predicate — so results are **bitwise identical**
//! to the scalar triple loop this replaced (pinned by
//! `blocked_grad_bitwise_matches_scalar_reference` below and the
//! golden THGS tests). Rewrites of these kernels must preserve that
//! per-accumulator op sequence or every golden test re-goldens.
//!
//! The axpy inner loops of the forward and weight-grad kernels run
//! eight accumulators per step through [`crate::util::simd::axpy_with`]
//! — vectorization **across** the independent `OUT_TILE` accumulators,
//! which leaves every accumulator's op sequence untouched (one
//! non-fused mul + add per `d_in`/row step), so the SIMD and scalar
//! paths are bitwise interchangeable (`FEDSPARSE_NO_SIMD=1` forces
//! scalar; `blocked_grad_bitwise_matches_scalar_reference` pins both).
//! The input-delta kernel's per-`(row, i)` accumulator is a *dot
//! product over `d_out`* — lane-parallelizing that sum would split it
//! into partial sums and reorder the f32 adds, which is exactly the
//! re-goldening event the contract forbids. Its vector branch instead
//! vectorizes across eight consecutive `i` via an AVX2 stride-`d_out`
//! gather (`dense_backward_input` docs); each lane still runs the
//! scalar add sequence, and non-AVX2 builds keep the scalar sweep.
//!
//! All buffers live in a reusable [`Workspace`], so steady-state
//! `grad_into`/`eval_into` calls allocate nothing.

use anyhow::{anyhow, bail, Result};

use crate::models::manifest::ModelMeta;
use crate::models::params::ParamVector;
use crate::util::simd;

use super::backend::Backend;

/// Batch rows processed together by the blocked kernels: each weight
/// row load is shared across the block.
const ROW_BLOCK: usize = 4;

/// Output-dimension tile width: `ROW_BLOCK × OUT_TILE` f32
/// accumulators (1 KiB) stay in registers/L1 while a `d_in × OUT_TILE`
/// weight strip streams through.
const OUT_TILE: usize = 64;

/// One dense layer's dimensions.
#[derive(Clone, Copy, Debug)]
struct DenseLayer {
    d_in: usize,
    d_out: usize,
}

/// Reusable scratch for one grad/eval call chain: per-layer activation
/// buffers plus the two backprop delta buffers, sized once for a model
/// + batch and reused every call ([`Backend::grad_into`] /
/// [`Backend::eval_into`]). Growing the batch re-sizes lazily;
/// steady-state calls perform zero heap allocations.
#[derive(Debug, Default)]
pub struct Workspace {
    /// Per-layer activations `[batch, d_out]` (post-ReLU for hidden
    /// layers, raw logits for the last).
    acts: Vec<Vec<f32>>,
    /// Backprop delta of the layer currently being walked.
    delta: Vec<f32>,
    /// Previous-layer delta under construction (swapped with `delta`
    /// after each layer).
    dprev: Vec<f32>,
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }
}

/// `out[r, :] = input[r, :]·W + bias` for a `[batch, d_in]` input,
/// ReLU fused into the store when `relu`.
fn dense_forward(
    input: &[f32],
    w: &[f32],
    bias: &[f32],
    out: &mut [f32],
    batch: usize,
    d_in: usize,
    d_out: usize,
    relu: bool,
    use_simd: bool,
) {
    debug_assert_eq!(input.len(), batch * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(bias.len(), d_out);
    debug_assert_eq!(out.len(), batch * d_out);
    let mut r0 = 0;
    while r0 < batch {
        let rb = (batch - r0).min(ROW_BLOCK);
        let mut t0 = 0;
        while t0 < d_out {
            let tw = (d_out - t0).min(OUT_TILE);
            let mut acc = [[0f32; OUT_TILE]; ROW_BLOCK];
            for a in acc.iter_mut().take(rb) {
                a[..tw].copy_from_slice(&bias[t0..t0 + tw]);
            }
            for i in 0..d_in {
                let mut xv = [0f32; ROW_BLOCK];
                let mut any = false;
                for r in 0..rb {
                    let v = input[(r0 + r) * d_in + i];
                    xv[r] = v;
                    any |= v != 0.0;
                }
                // mostly-zero inputs: skip the weight row when every
                // row of the block is zero at this column
                if !any {
                    continue;
                }
                let wrow = &w[i * d_out + t0..i * d_out + t0 + tw];
                for r in 0..rb {
                    let c = xv[r];
                    if c != 0.0 {
                        // axpy: acc_r += c · wrow, eight accumulators
                        // per SIMD step (ascending d_in per accumulator
                        // — the bitwise-identity invariant)
                        simd::axpy_with(&mut acc[r][..tw], c, wrow, use_simd);
                    }
                }
            }
            for r in 0..rb {
                let off = (r0 + r) * d_out + t0;
                let orow = &mut out[off..off + tw];
                if relu {
                    for (j, o) in orow.iter_mut().enumerate() {
                        let v = acc[r][j];
                        *o = if v < 0.0 { 0.0 } else { v };
                    }
                } else {
                    orow.copy_from_slice(&acc[r][..tw]);
                }
            }
            t0 += tw;
        }
        r0 += rb;
    }
}

/// Parameter gradients of one layer: `gw += a_prevᵀ·delta` (i-major so
/// each `gw` row is touched once per row block) and `gb += Σ_r
/// delta[r, :]`. Per (i, o) accumulator the adds land in ascending
/// batch-row order, exactly like the scalar sweep.
fn dense_backward_params(
    a_prev: &[f32],
    delta: &[f32],
    gw: &mut [f32],
    gb: &mut [f32],
    batch: usize,
    d_in: usize,
    d_out: usize,
    use_simd: bool,
) {
    debug_assert_eq!(a_prev.len(), batch * d_in);
    debug_assert_eq!(delta.len(), batch * d_out);
    debug_assert_eq!(gb.len(), d_out);
    let mut r0 = 0;
    while r0 < batch {
        let rb = (batch - r0).min(ROW_BLOCK);
        for r in r0..r0 + rb {
            let dr = &delta[r * d_out..(r + 1) * d_out];
            for (o, &dv) in dr.iter().enumerate() {
                gb[o] += dv;
            }
        }
        for i in 0..d_in {
            let mut av = [0f32; ROW_BLOCK];
            let mut any = false;
            for r in 0..rb {
                let v = a_prev[(r0 + r) * d_in + i];
                av[r] = v;
                any |= v != 0.0;
            }
            if !any {
                continue;
            }
            let gw_row = &mut gw[i * d_out..(i + 1) * d_out];
            for r in 0..rb {
                let c = av[r];
                if c != 0.0 {
                    // axpy: gw_row += c · delta_row, eight accumulators
                    // per SIMD step (ascending batch row per (i, o)
                    // accumulator — the bitwise-identity invariant)
                    let dr = &delta[(r0 + r) * d_out..(r0 + r + 1) * d_out];
                    simd::axpy_with(gw_row, c, dr, use_simd);
                }
            }
        }
        r0 += rb;
    }
}

/// Input delta of one layer: `dprev[r, i] = delta[r, :]·W[i, :]` where
/// the ReLU was live (`a_prev[r, i] > 0`), else 0. Every dot product
/// accumulates over ascending `d_out`, like the scalar sweep.
///
/// The vector branch keeps each per-`(r, i)` accumulator a *single*
/// f32 lane (never lane-splitting the `d_out` reduction, which would
/// reorder its adds and re-golden every pinned test) and instead
/// vectorizes **across** eight consecutive `i`: one AVX2 `vgatherdps`
/// pulls the stride-`d_out` column slice `W[i..i+8, o]`, which the
/// row block shares, and each lane does `acc += delta[r, o] · w` for
/// ascending `o` — the scalar op sequence exactly. Builds without a
/// hardware gather (`F32x8::HAS_GATHER` false: SSE2 baseline, NEON,
/// portable) and `FEDSPARSE_NO_SIMD=1` take the scalar sweep, which
/// remains the parity reference.
#[allow(clippy::too_many_arguments)]
fn dense_backward_input(
    a_prev: &[f32],
    delta: &[f32],
    w: &[f32],
    dprev: &mut [f32],
    batch: usize,
    d_in: usize,
    d_out: usize,
    use_simd: bool,
) {
    debug_assert_eq!(a_prev.len(), batch * d_in);
    debug_assert_eq!(delta.len(), batch * d_out);
    debug_assert_eq!(dprev.len(), batch * d_in);
    dprev.fill(0.0);
    let gather = use_simd && simd::F32x8::HAS_GATHER && d_in >= 8;
    let mut r0 = 0;
    while r0 < batch {
        let rb = (batch - r0).min(ROW_BLOCK);
        let mut i0 = 0;
        if gather {
            let idx = simd::GatherIdx::stride(d_out);
            while i0 + 8 <= d_in {
                // ReLU liveness per (row, lane); dead lanes are
                // computed and discarded at the store (the gather loads
                // stay in-bounds regardless: (i0+7)·d_out + o < len)
                let mut live = [[false; 8]; ROW_BLOCK];
                let mut row_any = [false; ROW_BLOCK];
                let mut any = false;
                for r in 0..rb {
                    for (l, lv) in live[r].iter_mut().enumerate() {
                        // a_prev > 0 ⟺ pre-activation > 0 (ReLU stored)
                        *lv = a_prev[(r0 + r) * d_in + i0 + l] > 0.0;
                        row_any[r] |= *lv;
                    }
                    any |= row_any[r];
                }
                if any {
                    let mut acc = [simd::F32x8::splat(0.0); ROW_BLOCK];
                    for o in 0..d_out {
                        // W[i0..i0+8, o], shared by the whole row block
                        let wv = simd::F32x8::gather(&w[i0 * d_out + o..], idx);
                        for r in 0..rb {
                            if row_any[r] {
                                let dv = simd::F32x8::splat(delta[(r0 + r) * d_out + o]);
                                acc[r] = acc[r].add(dv.mul(wv));
                            }
                        }
                    }
                    let mut out = [0f32; 8];
                    for r in 0..rb {
                        if row_any[r] {
                            acc[r].store(&mut out);
                            for (l, &lv) in live[r].iter().enumerate() {
                                if lv {
                                    dprev[(r0 + r) * d_in + i0 + l] = out[l];
                                }
                            }
                        }
                    }
                }
                i0 += 8;
            }
        }
        // scalar sweep: the whole range when gather is off, the
        // `d_in % 8` tail when it is on
        for i in i0..d_in {
            let mut live = [false; ROW_BLOCK];
            let mut any = false;
            for r in 0..rb {
                // a_prev > 0 ⟺ pre-activation > 0 (ReLU stored)
                let l = a_prev[(r0 + r) * d_in + i] > 0.0;
                live[r] = l;
                any |= l;
            }
            if !any {
                continue;
            }
            let wrow = &w[i * d_out..(i + 1) * d_out];
            for r in 0..rb {
                if live[r] {
                    let dr = &delta[(r0 + r) * d_out..(r0 + r + 1) * d_out];
                    let mut s = 0f32;
                    for (o, &wv) in wrow.iter().enumerate() {
                        s += dr[o] * wv;
                    }
                    dprev[(r0 + r) * d_in + i] = s;
                }
            }
        }
        r0 += rb;
    }
}

/// Bench-only entry to the backward-input kernel (`benches/
/// bench_kernels.rs` times the gather vs. scalar branches directly);
/// not part of the backend API.
#[doc(hidden)]
#[allow(clippy::too_many_arguments)]
pub fn bench_dense_backward_input(
    a_prev: &[f32],
    delta: &[f32],
    w: &[f32],
    dprev: &mut [f32],
    batch: usize,
    d_in: usize,
    d_out: usize,
    use_simd: bool,
) {
    dense_backward_input(a_prev, delta, w, dprev, batch, d_in, d_out, use_simd);
}

/// MLP forward/backward on flat parameter vectors.
pub struct NativeBackend {
    layers: Vec<DenseLayer>,
    classes: usize,
    /// Take the vectorized axpy branches (read once from
    /// [`simd::enabled`] at construction; bitwise-identical either way).
    use_simd: bool,
}

impl NativeBackend {
    /// Validate that `meta` describes an MLP this backend can run.
    pub fn new(meta: &ModelMeta) -> Result<Self> {
        let d0: usize = meta.input.iter().product();
        if meta.params.is_empty() || meta.params.len() % 2 != 0 {
            bail!(
                "native backend: model {:?} is not an MLP (expected alternating weight/bias params, got {})",
                meta.name,
                meta.params.len()
            );
        }
        let mut layers = Vec::with_capacity(meta.params.len() / 2);
        let mut expect_in = d0;
        for pair in meta.params.chunks(2) {
            let (w, b) = (&pair[0], &pair[1]);
            let (d_in, d_out) = match w.shape.as_slice() {
                [i, o] => (*i, *o),
                _ => bail!(
                    "native backend: param {:?} has shape {:?}, expected a 2-D weight",
                    w.name,
                    w.shape
                ),
            };
            if b.shape.as_slice() != [d_out] {
                bail!(
                    "native backend: bias {:?} has shape {:?}, expected [{d_out}]",
                    b.name,
                    b.shape
                );
            }
            if d_in != expect_in {
                bail!(
                    "native backend: layer {:?} takes input dim {d_in}, previous layer produces {expect_in}",
                    w.name
                );
            }
            expect_in = d_out;
            layers.push(DenseLayer { d_in, d_out });
        }
        if expect_in != meta.classes {
            bail!(
                "native backend: final layer emits {expect_in} logits, model has {} classes",
                meta.classes
            );
        }
        Ok(Self { layers, classes: meta.classes, use_simd: simd::enabled() })
    }

    /// Force the SIMD/scalar kernel branch. Parity-test and bench hook
    /// — the two branches are bitwise identical by the accumulator-
    /// order contract (module docs), so this is pure scheduling.
    pub fn set_simd(&mut self, on: bool) {
        self.use_simd = on;
    }

    fn check_batch(&self, params: &ParamVector, x: &[f32], y: &[i32]) -> Result<usize> {
        let b = y.len();
        let d0 = self.layers[0].d_in;
        if x.len() != b * d0 {
            return Err(anyhow!(
                "native backend: x has {} values, expected batch {b} × input {d0}",
                x.len()
            ));
        }
        if params.tensors.len() != 2 * self.layers.len() {
            return Err(anyhow!(
                "native backend: params hold {} tensors, model has {}",
                params.tensors.len(),
                2 * self.layers.len()
            ));
        }
        if let Some(&bad) = y.iter().find(|&&l| l < 0 || l as usize >= self.classes) {
            return Err(anyhow!("native backend: label {bad} outside 0..{}", self.classes));
        }
        Ok(b)
    }

    /// Size the workspace for this model + batch (no-op once warm).
    fn prepare(&self, ws: &mut Workspace, batch: usize) {
        ws.acts.resize_with(self.layers.len(), Vec::new);
        let mut max_out = 0;
        for (l, lay) in self.layers.iter().enumerate() {
            ws.acts[l].resize(batch * lay.d_out, 0.0);
            max_out = max_out.max(lay.d_out);
        }
        ws.delta.resize(batch * max_out, 0.0);
        ws.dprev.resize(batch * max_out, 0.0);
    }

    /// Forward pass into the workspace's per-layer activation buffers.
    fn forward_into(&self, params: &ParamVector, x: &[f32], batch: usize, ws: &mut Workspace) {
        let n_layers = self.layers.len();
        for (l, lay) in self.layers.iter().enumerate() {
            let (head, tail) = ws.acts.split_at_mut(l);
            let input: &[f32] = if l == 0 { x } else { &head[l - 1] };
            let out = &mut tail[0][..batch * lay.d_out];
            let w = params.tensor(2 * l);
            let bias = params.tensor(2 * l + 1);
            dense_forward(
                input,
                w,
                bias,
                out,
                batch,
                lay.d_in,
                lay.d_out,
                l + 1 < n_layers,
                self.use_simd,
            );
        }
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn grad(&self, params: &ParamVector, x: &[f32], y: &[i32]) -> Result<(f32, Vec<f32>)> {
        let mut ws = Workspace::new();
        let mut grads = Vec::new();
        let loss = self.grad_into(params, x, y, &mut ws, &mut grads)?;
        Ok((loss, grads))
    }

    fn grad_into(
        &self,
        params: &ParamVector,
        x: &[f32],
        y: &[i32],
        ws: &mut Workspace,
        grads: &mut Vec<f32>,
    ) -> Result<f32> {
        let b = self.check_batch(params, x, y)?;
        self.prepare(ws, b);
        self.forward_into(params, x, b, ws);
        let c = self.classes;

        // softmax + mean cross-entropy; `delta` becomes (p − onehot)/B
        let logits = ws.acts.last().unwrap();
        let delta = &mut ws.delta[..b * c];
        delta.copy_from_slice(&logits[..b * c]);
        let mut loss_sum = 0f64;
        for r in 0..b {
            let row = &mut delta[r * c..(r + 1) * c];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0f32;
            for v in row.iter_mut() {
                *v = (*v - max).exp();
                z += *v;
            }
            for v in row.iter_mut() {
                *v /= z;
            }
            loss_sum += -(row[y[r] as usize].max(1e-30) as f64).ln();
        }
        let inv_b = 1.0 / b as f32;
        for r in 0..b {
            delta[r * c + y[r] as usize] -= 1.0;
        }
        for v in delta.iter_mut() {
            *v *= inv_b;
        }

        // backward walk, filling the flat grad vector in manifest order
        grads.clear();
        grads.resize(params.len(), 0.0);
        for l in (0..self.layers.len()).rev() {
            let DenseLayer { d_in, d_out } = self.layers[l];
            let (w_off, w_len) = params.tensors[2 * l];
            let (b_off, b_len) = params.tensors[2 * l + 1];
            debug_assert_eq!(w_off + w_len, b_off, "bias not adjacent to weight");
            let (head, tail) = grads.split_at_mut(b_off);
            let gw = &mut head[w_off..];
            let gb = &mut tail[..b_len];
            {
                let a_prev: &[f32] = if l == 0 { x } else { &ws.acts[l - 1] };
                let delta = &ws.delta[..b * d_out];
                dense_backward_params(a_prev, delta, gw, gb, b, d_in, d_out, self.use_simd);
                if l > 0 {
                    // δ_prev = (δ · Wᵀ) ⊙ relu′
                    let w = params.tensor(2 * l);
                    dense_backward_input(
                        a_prev,
                        delta,
                        w,
                        &mut ws.dprev[..b * d_in],
                        b,
                        d_in,
                        d_out,
                        self.use_simd,
                    );
                }
            }
            if l > 0 {
                std::mem::swap(&mut ws.delta, &mut ws.dprev);
            }
        }
        Ok((loss_sum / b as f64) as f32)
    }

    fn eval_shard(&self, params: &ParamVector, x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        self.eval_into(params, x, y, &mut Workspace::new())
    }

    fn eval_into(
        &self,
        params: &ParamVector,
        x: &[f32],
        y: &[i32],
        ws: &mut Workspace,
    ) -> Result<(f32, f32)> {
        let b = self.check_batch(params, x, y)?;
        self.prepare(ws, b);
        self.forward_into(params, x, b, ws);
        let logits = ws.acts.last().unwrap();
        let c = self.classes;
        let mut loss_sum = 0f64;
        let mut correct = 0u32;
        for r in 0..b {
            let row = &logits[r * c..(r + 1) * c];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0f32;
            let mut argmax = 0usize;
            for (o, &v) in row.iter().enumerate() {
                z += (v - max).exp();
                if v > row[argmax] {
                    argmax = o;
                }
            }
            // per-sample CE: ln Σe^{v−max} + max − v_y
            loss_sum += (z as f64).ln() + max as f64 - row[y[r] as usize] as f64;
            if argmax == y[r] as usize {
                correct += 1;
            }
        }
        Ok((loss_sum as f32, correct as f32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::manifest::{InitKind, LayerGroup, ParamSpec};
    use crate::util::rng::Rng;

    /// A tiny 4→6→3 MLP meta for unit tests.
    pub(crate) fn tiny_meta() -> ModelMeta {
        let spec = |name: &str, shape: Vec<usize>, layer: usize| ParamSpec {
            name: name.into(),
            shape,
            init: InitKind::Normal { std: 0.4 },
            layer,
        };
        ModelMeta {
            name: "tiny_mlp".into(),
            input: vec![4],
            classes: 3,
            params: vec![
                spec("l0/w", vec![4, 6], 0),
                ParamSpec { init: InitKind::Zeros, ..spec("l0/b", vec![6], 0) },
                spec("l1/w", vec![6, 3], 1),
                ParamSpec { init: InitKind::Zeros, ..spec("l1/b", vec![3], 1) },
            ],
            layers: vec![
                LayerGroup { name: "l0".into(), params: vec![0, 1] },
                LayerGroup { name: "l1".into(), params: vec![2, 3] },
            ],
            param_count: 4 * 6 + 6 + 6 * 3 + 3,
            grad_artifact: String::new(),
            eval_artifact: String::new(),
        }
    }

    /// An 8→100→3 MLP: its hidden layer spans two OUT_TILE strips
    /// (100 = 64 + 36), so the parity tests exercise the multi-tile
    /// path and the tile tail the tiny meta (d_out ≤ 6) cannot reach.
    fn wide_meta() -> ModelMeta {
        let spec = |name: &str, shape: Vec<usize>, layer: usize| ParamSpec {
            name: name.into(),
            shape,
            init: InitKind::Normal { std: 0.3 },
            layer,
        };
        ModelMeta {
            name: "wide_mlp".into(),
            input: vec![8],
            classes: 3,
            params: vec![
                spec("l0/w", vec![8, 100], 0),
                ParamSpec { init: InitKind::Zeros, ..spec("l0/b", vec![100], 0) },
                spec("l1/w", vec![100, 3], 1),
                ParamSpec { init: InitKind::Zeros, ..spec("l1/b", vec![3], 1) },
            ],
            layers: vec![
                LayerGroup { name: "l0".into(), params: vec![0, 1] },
                LayerGroup { name: "l1".into(), params: vec![2, 3] },
            ],
            param_count: 8 * 100 + 100 + 100 * 3 + 3,
            grad_artifact: String::new(),
            eval_artifact: String::new(),
        }
    }

    /// An 8→65→9 MLP: d_out 65 drives the axpy through a full 8-lane
    /// tile run plus a 1-lane remainder, and d_out 9 through one SIMD
    /// group plus 1 — the lane-remainder widths the tiny/wide metas
    /// (6/3, 100/3) do not hit.
    fn lane_meta() -> ModelMeta {
        let spec = |name: &str, shape: Vec<usize>, layer: usize| ParamSpec {
            name: name.into(),
            shape,
            init: InitKind::Normal { std: 0.3 },
            layer,
        };
        ModelMeta {
            name: "lane_mlp".into(),
            input: vec![8],
            classes: 9,
            params: vec![
                spec("l0/w", vec![8, 65], 0),
                ParamSpec { init: InitKind::Zeros, ..spec("l0/b", vec![65], 0) },
                spec("l1/w", vec![65, 9], 1),
                ParamSpec { init: InitKind::Zeros, ..spec("l1/b", vec![9], 1) },
            ],
            layers: vec![
                LayerGroup { name: "l0".into(), params: vec![0, 1] },
                LayerGroup { name: "l1".into(), params: vec![2, 3] },
            ],
            param_count: 8 * 65 + 65 + 65 * 9 + 9,
            grad_artifact: String::new(),
            eval_artifact: String::new(),
        }
    }

    fn batch(meta: &ModelMeta, b: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let d: usize = meta.input.iter().product();
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..b * d).map(|_| rng.normal_f32(1.0)).collect();
        let y: Vec<i32> = (0..b).map(|_| (rng.below(meta.classes as u64)) as i32).collect();
        (x, y)
    }

    /// The pre-blocking scalar forward (verbatim from the original
    /// implementation) — the reference the blocked kernels must match
    /// bitwise.
    fn reference_forward(
        be: &NativeBackend,
        params: &ParamVector,
        x: &[f32],
        batch: usize,
    ) -> Vec<Vec<f32>> {
        let n_layers = be.layers.len();
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(n_layers);
        for (l, lay) in be.layers.iter().enumerate() {
            let input: &[f32] = if l == 0 { x } else { &acts[l - 1] };
            let w = params.tensor(2 * l);
            let bias = params.tensor(2 * l + 1);
            let mut out = vec![0f32; batch * lay.d_out];
            for r in 0..batch {
                let xr = &input[r * lay.d_in..(r + 1) * lay.d_in];
                let or = &mut out[r * lay.d_out..(r + 1) * lay.d_out];
                or.copy_from_slice(bias);
                for (i, &xv) in xr.iter().enumerate() {
                    if xv != 0.0 {
                        let wrow = &w[i * lay.d_out..(i + 1) * lay.d_out];
                        for (o, &wv) in wrow.iter().enumerate() {
                            or[o] += xv * wv;
                        }
                    }
                }
                if l + 1 < n_layers {
                    for v in or.iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
            }
            acts.push(out);
        }
        acts
    }

    /// The pre-blocking scalar grad (verbatim from the original
    /// implementation).
    fn reference_grad(
        be: &NativeBackend,
        params: &ParamVector,
        x: &[f32],
        y: &[i32],
    ) -> (f32, Vec<f32>) {
        let b = y.len();
        let acts = reference_forward(be, params, x, b);
        let c = be.classes;
        let logits = acts.last().unwrap();
        let mut delta = logits.clone();
        let mut loss_sum = 0f64;
        for r in 0..b {
            let row = &mut delta[r * c..(r + 1) * c];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0f32;
            for v in row.iter_mut() {
                *v = (*v - max).exp();
                z += *v;
            }
            for v in row.iter_mut() {
                *v /= z;
            }
            loss_sum += -(row[y[r] as usize].max(1e-30) as f64).ln();
        }
        let inv_b = 1.0 / b as f32;
        for r in 0..b {
            delta[r * c + y[r] as usize] -= 1.0;
        }
        for v in delta.iter_mut() {
            *v *= inv_b;
        }

        let mut grads = vec![0f32; params.len()];
        for l in (0..be.layers.len()).rev() {
            let DenseLayer { d_in, d_out } = be.layers[l];
            let a_prev: &[f32] = if l == 0 { x } else { &acts[l - 1] };
            let (w_off, w_len) = params.tensors[2 * l];
            let (b_off, b_len) = params.tensors[2 * l + 1];
            assert_eq!(w_off + w_len, b_off, "bias not adjacent to weight");
            let (head, tail) = grads.split_at_mut(b_off);
            let gw = &mut head[w_off..];
            let gb = &mut tail[..b_len];
            for r in 0..b {
                let dr = &delta[r * d_out..(r + 1) * d_out];
                for (o, &dv) in dr.iter().enumerate() {
                    gb[o] += dv;
                }
                let ar = &a_prev[r * d_in..(r + 1) * d_in];
                for (i, &av) in ar.iter().enumerate() {
                    if av != 0.0 {
                        let gw_row = &mut gw[i * d_out..(i + 1) * d_out];
                        for (o, &dv) in dr.iter().enumerate() {
                            gw_row[o] += av * dv;
                        }
                    }
                }
            }
            if l > 0 {
                let w = params.tensor(2 * l);
                let mut dprev = vec![0f32; b * d_in];
                for r in 0..b {
                    let dr = &delta[r * d_out..(r + 1) * d_out];
                    let ar = &a_prev[r * d_in..(r + 1) * d_in];
                    let dp = &mut dprev[r * d_in..(r + 1) * d_in];
                    for i in 0..d_in {
                        if ar[i] > 0.0 {
                            let wrow = &w[i * d_out..(i + 1) * d_out];
                            let mut s = 0f32;
                            for (o, &dv) in dr.iter().enumerate() {
                                s += dv * wrow[o];
                            }
                            dp[i] = s;
                        }
                    }
                }
                delta = dprev;
            }
        }
        ((loss_sum / b as f64) as f32, grads)
    }

    #[test]
    fn blocked_grad_bitwise_matches_scalar_reference() {
        // batch 1/3/4/17 exercise the ROW_BLOCK remainder paths (0, 3,
        // 0, 1 leftover rows); tiny_meta's d_out 6/3 exercise the
        // sub-tile case, wide_meta's d_out 100 the multi-tile path
        // (64 + 36) with a tile tail, lane_meta's 65/9 the 8-lane SIMD
        // group remainders. Both kernel branches (vectorized axpy and
        // forced scalar) must match the reference bitwise.
        for meta in [tiny_meta(), wide_meta(), lane_meta()] {
            for use_simd in [true, false] {
                let mut be = NativeBackend::new(&meta).unwrap();
                be.set_simd(use_simd);
                for (seed, b) in [(21u64, 1usize), (22, 3), (23, 4), (24, 17)] {
                    let params = ParamVector::init(&meta, seed);
                    let (x, y) = batch(&meta, b, seed ^ 0xb17);
                    let (loss_new, grads_new) = be.grad(&params, &x, &y).unwrap();
                    let (loss_ref, grads_ref) = reference_grad(&be, &params, &x, &y);
                    assert_eq!(
                        loss_new.to_bits(),
                        loss_ref.to_bits(),
                        "loss at {}/batch {b}/simd {use_simd}",
                        meta.name
                    );
                    assert_eq!(grads_new.len(), grads_ref.len());
                    for i in 0..grads_new.len() {
                        assert_eq!(
                            grads_new[i].to_bits(),
                            grads_ref[i].to_bits(),
                            "grad[{i}] differs at {}/batch {b}/simd {use_simd}: {} vs {}",
                            meta.name,
                            grads_new[i],
                            grads_ref[i]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn gather_backward_input_bitwise_matches_scalar_at_remainder_widths() {
        // The gather branch vectorizes across eight consecutive `i`,
        // so the kernel-level remainder axis is d_in: 7/8/9 pin
        // below/at/above one gather group, 65 a full tile run plus 1.
        // Batches 1/3/4/17 cover the ROW_BLOCK remainders. On AVX2
        // builds use_simd=true takes the real vgatherdps path; on
        // others HAS_GATHER routes both calls through the scalar
        // sweep, which must be equal trivially. ReLU-dead cells
        // (a_prev ≤ 0) must stay exactly 0 in both branches.
        let mut rng = Rng::new(0x9a77);
        for &d_in in &[7usize, 8, 9, 65] {
            for &d_out in &[3usize, 9] {
                for &batch in &[1usize, 3, 4, 17] {
                    let a_prev: Vec<f32> = (0..batch * d_in)
                        .map(|_| {
                            // ~1/3 dead lanes: zeros and negatives both
                            // count as ReLU-dead
                            match rng.below(3) {
                                0 => 0.0,
                                1 => -rng.normal_f32(1.0).abs(),
                                _ => rng.normal_f32(1.0).abs() + 1e-3,
                            }
                        })
                        .collect();
                    let delta: Vec<f32> =
                        (0..batch * d_out).map(|_| rng.normal_f32(0.5)).collect();
                    let w: Vec<f32> = (0..d_in * d_out).map(|_| rng.normal_f32(0.3)).collect();
                    let mut out_simd = vec![f32::NAN; batch * d_in];
                    let mut out_scalar = vec![f32::NAN; batch * d_in];
                    dense_backward_input(
                        &a_prev, &delta, &w, &mut out_simd, batch, d_in, d_out, true,
                    );
                    dense_backward_input(
                        &a_prev, &delta, &w, &mut out_scalar, batch, d_in, d_out, false,
                    );
                    for i in 0..out_simd.len() {
                        assert_eq!(
                            out_simd[i].to_bits(),
                            out_scalar[i].to_bits(),
                            "d_in={d_in} d_out={d_out} batch={batch} cell={i}: {} vs {}",
                            out_simd[i],
                            out_scalar[i]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn blocked_forward_bitwise_matches_scalar_reference() {
        for meta in [tiny_meta(), wide_meta(), lane_meta()] {
            for use_simd in [true, false] {
                let mut be = NativeBackend::new(&meta).unwrap();
                be.set_simd(use_simd);
                let params = ParamVector::init(&meta, 31);
                for b in [1usize, 3, 4, 17] {
                    let (x, _) = batch(&meta, b, 7 + b as u64);
                    let mut ws = Workspace::new();
                    be.prepare(&mut ws, b);
                    be.forward_into(&params, &x, b, &mut ws);
                    let reference = reference_forward(&be, &params, &x, b);
                    for (l, r) in ws.acts.iter().zip(&reference) {
                        assert_eq!(l.len(), r.len());
                        for (a, c) in l.iter().zip(r) {
                            assert_eq!(
                                a.to_bits(),
                                c.to_bits(),
                                "{}/batch {b}/simd {use_simd}",
                                meta.name
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn workspace_reuse_is_transparent() {
        // one workspace driven across shrinking/growing batches must
        // give the same answers as fresh workspaces
        let meta = tiny_meta();
        let be = NativeBackend::new(&meta).unwrap();
        let params = ParamVector::init(&meta, 41);
        let mut ws = Workspace::new();
        let mut grads = Vec::new();
        for b in [17usize, 3, 4, 1, 17] {
            let (x, y) = batch(&meta, b, 100 + b as u64);
            let loss = be.grad_into(&params, &x, &y, &mut ws, &mut grads).unwrap();
            let (loss_fresh, grads_fresh) = be.grad(&params, &x, &y).unwrap();
            assert_eq!(loss.to_bits(), loss_fresh.to_bits());
            assert_eq!(grads, grads_fresh);
            let (l1, c1) = be.eval_into(&params, &x, &y, &mut ws).unwrap();
            let (l2, c2) = be.eval_shard(&params, &x, &y).unwrap();
            assert_eq!(l1.to_bits(), l2.to_bits());
            assert_eq!(c1, c2);
        }
    }

    #[test]
    fn rejects_non_mlp_shapes() {
        let mut meta = tiny_meta();
        meta.params[0].shape = vec![4, 6, 1]; // conv-ish
        assert!(NativeBackend::new(&meta).is_err());
        let mut meta = tiny_meta();
        meta.params.pop(); // odd param count
        assert!(NativeBackend::new(&meta).is_err());
        let mut meta = tiny_meta();
        meta.classes = 7; // logits ≠ classes
        assert!(NativeBackend::new(&meta).is_err());
    }

    #[test]
    fn init_loss_is_ln_classes() {
        let meta = tiny_meta();
        let be = NativeBackend::new(&meta).unwrap();
        let params = ParamVector::init(&meta, 3);
        let (x, y) = batch(&meta, 64, 5);
        let (loss, grads) = be.grad(&params, &x, &y).unwrap();
        assert_eq!(grads.len(), meta.total_params());
        // small random weights ⇒ near-uniform softmax ⇒ loss ≈ ln 3
        assert!((loss - (3f32).ln()).abs() < 0.5, "init loss {loss}");
    }

    #[test]
    fn sgd_descends_on_fixed_batch() {
        let meta = tiny_meta();
        let be = NativeBackend::new(&meta).unwrap();
        let mut params = ParamVector::init(&meta, 7);
        let (x, y) = batch(&meta, 32, 9);
        let (loss0, _) = be.grad(&params, &x, &y).unwrap();
        for _ in 0..30 {
            let (_, g) = be.grad(&params, &x, &y).unwrap();
            params.sgd_step(&g, 0.5);
        }
        let (loss1, _) = be.grad(&params, &x, &y).unwrap();
        assert!(loss1 < loss0 * 0.5, "no descent: {loss0} → {loss1}");
    }

    #[test]
    fn eval_shard_counts_match_grad_loss() {
        let meta = tiny_meta();
        let be = NativeBackend::new(&meta).unwrap();
        let params = ParamVector::init(&meta, 11);
        let (x, y) = batch(&meta, 50, 13);
        let (mean_loss, _) = be.grad(&params, &x, &y).unwrap();
        let (loss_sum, correct) = be.eval_shard(&params, &x, &y).unwrap();
        assert!((loss_sum / 50.0 - mean_loss).abs() < 1e-4);
        assert!((0.0..=50.0).contains(&correct));
    }

    #[test]
    fn bad_inputs_error() {
        let meta = tiny_meta();
        let be = NativeBackend::new(&meta).unwrap();
        let params = ParamVector::init(&meta, 1);
        assert!(be.grad(&params, &[0.0; 7], &[0, 1]).is_err()); // x len
        assert!(be.grad(&params, &[0.0; 8], &[0, 3]).is_err()); // label range
    }
}
