//! Pure-Rust compute backend: forward / grad / eval for the MLP model
//! family, straight on flat [`ParamVector`] slices.
//!
//! The manifest's MLP models (`mnist_mlp`: 784→200→10, 159,010
//! params) are alternating `(weight [d_in, d_out], bias [d_out])`
//! pairs with ReLU between layers and softmax-cross-entropy at the
//! top — exactly what the AOT grad/eval artifacts compute. This
//! implementation reproduces that math in plain loops, so the full
//! federated round loop runs deterministically on any machine with no
//! Python, JAX, or PJRT artifacts.
//!
//! Layouts are row-major throughout: activations `[batch, d]`,
//! weights `[d_in, d_out]` (manifest order). Gradients come back as
//! one flat vector in manifest parameter order, like the PJRT path.

use anyhow::{anyhow, bail, Result};

use crate::models::manifest::ModelMeta;
use crate::models::params::ParamVector;

use super::backend::Backend;

/// One dense layer's dimensions.
#[derive(Clone, Copy, Debug)]
struct DenseLayer {
    d_in: usize,
    d_out: usize,
}

/// MLP forward/backward on flat parameter vectors.
pub struct NativeBackend {
    layers: Vec<DenseLayer>,
    classes: usize,
}

impl NativeBackend {
    /// Validate that `meta` describes an MLP this backend can run.
    pub fn new(meta: &ModelMeta) -> Result<Self> {
        let d0: usize = meta.input.iter().product();
        if meta.params.is_empty() || meta.params.len() % 2 != 0 {
            bail!(
                "native backend: model {:?} is not an MLP (expected alternating weight/bias params, got {})",
                meta.name,
                meta.params.len()
            );
        }
        let mut layers = Vec::with_capacity(meta.params.len() / 2);
        let mut expect_in = d0;
        for pair in meta.params.chunks(2) {
            let (w, b) = (&pair[0], &pair[1]);
            let (d_in, d_out) = match w.shape.as_slice() {
                [i, o] => (*i, *o),
                _ => bail!(
                    "native backend: param {:?} has shape {:?}, expected a 2-D weight",
                    w.name,
                    w.shape
                ),
            };
            if b.shape.as_slice() != [d_out] {
                bail!(
                    "native backend: bias {:?} has shape {:?}, expected [{d_out}]",
                    b.name,
                    b.shape
                );
            }
            if d_in != expect_in {
                bail!(
                    "native backend: layer {:?} takes input dim {d_in}, previous layer produces {expect_in}",
                    w.name
                );
            }
            expect_in = d_out;
            layers.push(DenseLayer { d_in, d_out });
        }
        if expect_in != meta.classes {
            bail!(
                "native backend: final layer emits {expect_in} logits, model has {} classes",
                meta.classes
            );
        }
        Ok(Self { layers, classes: meta.classes })
    }

    fn check_batch(&self, params: &ParamVector, x: &[f32], y: &[i32]) -> Result<usize> {
        let b = y.len();
        let d0 = self.layers[0].d_in;
        if x.len() != b * d0 {
            return Err(anyhow!(
                "native backend: x has {} values, expected batch {b} × input {d0}",
                x.len()
            ));
        }
        if params.tensors.len() != 2 * self.layers.len() {
            return Err(anyhow!(
                "native backend: params hold {} tensors, model has {}",
                params.tensors.len(),
                2 * self.layers.len()
            ));
        }
        if let Some(&bad) = y.iter().find(|&&l| l < 0 || l as usize >= self.classes) {
            return Err(anyhow!("native backend: label {bad} outside 0..{}", self.classes));
        }
        Ok(b)
    }

    /// Forward pass; returns one activation buffer per layer
    /// (post-ReLU for hidden layers, raw logits for the last).
    fn forward(&self, params: &ParamVector, x: &[f32], batch: usize) -> Vec<Vec<f32>> {
        let n_layers = self.layers.len();
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(n_layers);
        for (l, lay) in self.layers.iter().enumerate() {
            let input: &[f32] = if l == 0 { x } else { &acts[l - 1] };
            let w = params.tensor(2 * l);
            let bias = params.tensor(2 * l + 1);
            let mut out = vec![0f32; batch * lay.d_out];
            for r in 0..batch {
                let xr = &input[r * lay.d_in..(r + 1) * lay.d_in];
                let or = &mut out[r * lay.d_out..(r + 1) * lay.d_out];
                or.copy_from_slice(bias);
                for (i, &xv) in xr.iter().enumerate() {
                    // image pixels and ReLU activations are mostly
                    // zero — skipping them is the hot-path win
                    if xv != 0.0 {
                        let wrow = &w[i * lay.d_out..(i + 1) * lay.d_out];
                        for (o, &wv) in wrow.iter().enumerate() {
                            or[o] += xv * wv;
                        }
                    }
                }
                if l + 1 < n_layers {
                    for v in or.iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
            }
            acts.push(out);
        }
        acts
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn grad(&self, params: &ParamVector, x: &[f32], y: &[i32]) -> Result<(f32, Vec<f32>)> {
        let b = self.check_batch(params, x, y)?;
        let acts = self.forward(params, x, b);
        let c = self.classes;

        // softmax + mean cross-entropy; `delta` becomes (p − onehot)/B
        let logits = acts.last().unwrap();
        let mut delta = logits.clone();
        let mut loss_sum = 0f64;
        for r in 0..b {
            let row = &mut delta[r * c..(r + 1) * c];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0f32;
            for v in row.iter_mut() {
                *v = (*v - max).exp();
                z += *v;
            }
            for v in row.iter_mut() {
                *v /= z;
            }
            loss_sum += -(row[y[r] as usize].max(1e-30) as f64).ln();
        }
        let inv_b = 1.0 / b as f32;
        for r in 0..b {
            delta[r * c + y[r] as usize] -= 1.0;
        }
        for v in delta.iter_mut() {
            *v *= inv_b;
        }

        // backward walk, filling the flat grad vector in manifest order
        let mut grads = vec![0f32; params.len()];
        for l in (0..self.layers.len()).rev() {
            let DenseLayer { d_in, d_out } = self.layers[l];
            let a_prev: &[f32] = if l == 0 { x } else { &acts[l - 1] };
            let (w_off, w_len) = params.tensors[2 * l];
            let (b_off, b_len) = params.tensors[2 * l + 1];
            debug_assert_eq!(w_off + w_len, b_off, "bias not adjacent to weight");
            let (head, tail) = grads.split_at_mut(b_off);
            let gw = &mut head[w_off..];
            let gb = &mut tail[..b_len];
            for r in 0..b {
                let dr = &delta[r * d_out..(r + 1) * d_out];
                for (o, &dv) in dr.iter().enumerate() {
                    gb[o] += dv;
                }
                let ar = &a_prev[r * d_in..(r + 1) * d_in];
                for (i, &av) in ar.iter().enumerate() {
                    if av != 0.0 {
                        let gw_row = &mut gw[i * d_out..(i + 1) * d_out];
                        for (o, &dv) in dr.iter().enumerate() {
                            gw_row[o] += av * dv;
                        }
                    }
                }
            }
            if l > 0 {
                // δ_prev = (δ · Wᵀ) ⊙ relu′; a_prev > 0 ⟺ pre-act > 0
                let w = params.tensor(2 * l);
                let mut dprev = vec![0f32; b * d_in];
                for r in 0..b {
                    let dr = &delta[r * d_out..(r + 1) * d_out];
                    let ar = &a_prev[r * d_in..(r + 1) * d_in];
                    let dp = &mut dprev[r * d_in..(r + 1) * d_in];
                    for i in 0..d_in {
                        if ar[i] > 0.0 {
                            let wrow = &w[i * d_out..(i + 1) * d_out];
                            let mut s = 0f32;
                            for (o, &dv) in dr.iter().enumerate() {
                                s += dv * wrow[o];
                            }
                            dp[i] = s;
                        }
                    }
                }
                delta = dprev;
            }
        }
        Ok(((loss_sum / b as f64) as f32, grads))
    }

    fn eval_shard(&self, params: &ParamVector, x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let b = self.check_batch(params, x, y)?;
        let acts = self.forward(params, x, b);
        let logits = acts.last().unwrap();
        let c = self.classes;
        let mut loss_sum = 0f64;
        let mut correct = 0u32;
        for r in 0..b {
            let row = &logits[r * c..(r + 1) * c];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0f32;
            let mut argmax = 0usize;
            for (o, &v) in row.iter().enumerate() {
                z += (v - max).exp();
                if v > row[argmax] {
                    argmax = o;
                }
            }
            // per-sample CE: ln Σe^{v−max} + max − v_y
            loss_sum += (z as f64).ln() + max as f64 - row[y[r] as usize] as f64;
            if argmax == y[r] as usize {
                correct += 1;
            }
        }
        Ok((loss_sum as f32, correct as f32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::manifest::{InitKind, LayerGroup, ParamSpec};
    use crate::util::rng::Rng;

    /// A tiny 4→6→3 MLP meta for unit tests.
    pub(crate) fn tiny_meta() -> ModelMeta {
        let spec = |name: &str, shape: Vec<usize>, layer: usize| ParamSpec {
            name: name.into(),
            shape,
            init: InitKind::Normal { std: 0.4 },
            layer,
        };
        ModelMeta {
            name: "tiny_mlp".into(),
            input: vec![4],
            classes: 3,
            params: vec![
                spec("l0/w", vec![4, 6], 0),
                ParamSpec { init: InitKind::Zeros, ..spec("l0/b", vec![6], 0) },
                spec("l1/w", vec![6, 3], 1),
                ParamSpec { init: InitKind::Zeros, ..spec("l1/b", vec![3], 1) },
            ],
            layers: vec![
                LayerGroup { name: "l0".into(), params: vec![0, 1] },
                LayerGroup { name: "l1".into(), params: vec![2, 3] },
            ],
            param_count: 4 * 6 + 6 + 6 * 3 + 3,
            grad_artifact: String::new(),
            eval_artifact: String::new(),
        }
    }

    fn batch(meta: &ModelMeta, b: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let d: usize = meta.input.iter().product();
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..b * d).map(|_| rng.normal_f32(1.0)).collect();
        let y: Vec<i32> = (0..b).map(|_| (rng.below(meta.classes as u64)) as i32).collect();
        (x, y)
    }

    #[test]
    fn rejects_non_mlp_shapes() {
        let mut meta = tiny_meta();
        meta.params[0].shape = vec![4, 6, 1]; // conv-ish
        assert!(NativeBackend::new(&meta).is_err());
        let mut meta = tiny_meta();
        meta.params.pop(); // odd param count
        assert!(NativeBackend::new(&meta).is_err());
        let mut meta = tiny_meta();
        meta.classes = 7; // logits ≠ classes
        assert!(NativeBackend::new(&meta).is_err());
    }

    #[test]
    fn init_loss_is_ln_classes() {
        let meta = tiny_meta();
        let be = NativeBackend::new(&meta).unwrap();
        let params = ParamVector::init(&meta, 3);
        let (x, y) = batch(&meta, 64, 5);
        let (loss, grads) = be.grad(&params, &x, &y).unwrap();
        assert_eq!(grads.len(), meta.total_params());
        // small random weights ⇒ near-uniform softmax ⇒ loss ≈ ln 3
        assert!((loss - (3f32).ln()).abs() < 0.5, "init loss {loss}");
    }

    #[test]
    fn sgd_descends_on_fixed_batch() {
        let meta = tiny_meta();
        let be = NativeBackend::new(&meta).unwrap();
        let mut params = ParamVector::init(&meta, 7);
        let (x, y) = batch(&meta, 32, 9);
        let (loss0, _) = be.grad(&params, &x, &y).unwrap();
        for _ in 0..30 {
            let (_, g) = be.grad(&params, &x, &y).unwrap();
            params.sgd_step(&g, 0.5);
        }
        let (loss1, _) = be.grad(&params, &x, &y).unwrap();
        assert!(loss1 < loss0 * 0.5, "no descent: {loss0} → {loss1}");
    }

    #[test]
    fn eval_shard_counts_match_grad_loss() {
        let meta = tiny_meta();
        let be = NativeBackend::new(&meta).unwrap();
        let params = ParamVector::init(&meta, 11);
        let (x, y) = batch(&meta, 50, 13);
        let (mean_loss, _) = be.grad(&params, &x, &y).unwrap();
        let (loss_sum, correct) = be.eval_shard(&params, &x, &y).unwrap();
        assert!((loss_sum / 50.0 - mean_loss).abs() < 1e-4);
        assert!((0.0..=50.0).contains(&correct));
    }

    #[test]
    fn bad_inputs_error() {
        let meta = tiny_meta();
        let be = NativeBackend::new(&meta).unwrap();
        let params = ParamVector::init(&meta, 1);
        assert!(be.grad(&params, &[0.0; 7], &[0, 1]).is_err()); // x len
        assert!(be.grad(&params, &[0.0; 8], &[0, 3]).is_err()); // label range
    }
}
