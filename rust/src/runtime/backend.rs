//! The compute-backend abstraction.
//!
//! A [`Backend`] evaluates one model's forward/grad/eval graphs on
//! flat [`ParamVector`] slices. Two implementations exist:
//!
//! * [`crate::runtime::native::NativeBackend`] — pure Rust, always
//!   available, deterministic, no artifacts required (MLP models)
//! * `PjrtBackend` (feature `pjrt`) — executes the AOT-exported HLO
//!   artifacts through the PJRT C API (any exported model)
//!
//! [`ModelRunner`] is the coordinator-facing façade: it owns the
//! backend, enforces the manifest batch sizes and provides the
//! full-dataset evaluation loop. [`BackendKind`] is the user-facing
//! selector ([`crate::config::RunConfig::backend`]).

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::config::RunConfig;
use crate::models::manifest::{Manifest, ModelMeta};
use crate::models::params::ParamVector;

use super::native::{NativeBackend, Workspace};

/// One model's compute implementation. Implementations must be usable
/// concurrently from the client worker pool (`Send + Sync`).
pub trait Backend: Send + Sync {
    /// Short stable identifier (`"native"`, `"pjrt"`).
    fn name(&self) -> &'static str;

    /// One grad step on a batch: returns `(mean_loss, flat_grads)`.
    /// `x` is NHWC flattened (len = batch · prod(input)), `y` labels.
    fn grad(&self, params: &ParamVector, x: &[f32], y: &[i32]) -> Result<(f32, Vec<f32>)>;

    /// Evaluate one shard: returns `(loss_sum, correct_count)`.
    fn eval_shard(&self, params: &ParamVector, x: &[f32], y: &[i32]) -> Result<(f32, f32)>;

    /// [`Self::grad`] into caller-owned scratch: activations/deltas
    /// come from `ws`, the flat gradient lands in `grads` (resized to
    /// the model). Identical results to [`Self::grad`]; the round
    /// engine's per-worker workspaces ride this so steady-state local
    /// training performs zero heap allocations. Backends without a
    /// workspace-aware path fall back to [`Self::grad`].
    fn grad_into(
        &self,
        params: &ParamVector,
        x: &[f32],
        y: &[i32],
        _ws: &mut Workspace,
        grads: &mut Vec<f32>,
    ) -> Result<f32> {
        let (loss, g) = self.grad(params, x, y)?;
        *grads = g; // hand the buffer over, no copy
        Ok(loss)
    }

    /// [`Self::eval_shard`] against caller-owned scratch (same
    /// fallback contract as [`Self::grad_into`]).
    fn eval_into(
        &self,
        params: &ParamVector,
        x: &[f32],
        y: &[i32],
        _ws: &mut Workspace,
    ) -> Result<(f32, f32)> {
        self.eval_shard(params, x, y)
    }
}

/// User-facing backend selection.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendKind {
    /// PJRT when the build has the `pjrt` feature AND the model's
    /// artifacts exist on disk; the native backend otherwise.
    #[default]
    Auto,
    /// Pure-Rust compute; no artifacts needed (MLP models only).
    Native,
    /// AOT artifacts through PJRT; errors when unavailable.
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "auto" => Some(Self::Auto),
            "native" => Some(Self::Native),
            "pjrt" => Some(Self::Pjrt),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Auto => "auto",
            Self::Native => "native",
            Self::Pjrt => "pjrt",
        }
    }
}

/// Do the model's AOT artifacts exist under the manifest directory?
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
fn artifacts_present(manifest: &Manifest, meta: &ModelMeta) -> bool {
    manifest.artifact_path(&meta.grad_artifact).exists()
        && manifest.artifact_path(&meta.eval_artifact).exists()
}

/// Resolve `cfg.backend` against what this build and machine offer.
fn resolve_backend(
    manifest: &Manifest,
    meta: &ModelMeta,
    cfg: &RunConfig,
) -> Result<Arc<dyn Backend>> {
    // silence unused warnings in the no-pjrt build
    let _ = (manifest, cfg.exec_workers);
    match cfg.backend {
        BackendKind::Native => Ok(Arc::new(NativeBackend::new(meta)?) as Arc<dyn Backend>),
        BackendKind::Pjrt => {
            #[cfg(feature = "pjrt")]
            {
                if !artifacts_present(manifest, meta) {
                    return Err(anyhow!(
                        "backend pjrt: artifacts for {:?} not found under {:?} (run `make artifacts`)",
                        meta.name,
                        manifest.dir
                    ));
                }
                Ok(Arc::new(super::runner::PjrtBackend::new(
                    manifest,
                    meta,
                    cfg.exec_workers,
                )) as Arc<dyn Backend>)
            }
            #[cfg(not(feature = "pjrt"))]
            {
                Err(anyhow!(
                    "backend pjrt requested but this build has no `pjrt` feature \
                     (rebuild with `--features pjrt`, or use the native backend)"
                ))
            }
        }
        BackendKind::Auto => {
            #[cfg(feature = "pjrt")]
            {
                if artifacts_present(manifest, meta) {
                    return Ok(Arc::new(super::runner::PjrtBackend::new(
                        manifest,
                        meta,
                        cfg.exec_workers,
                    )) as Arc<dyn Backend>);
                }
            }
            NativeBackend::new(meta)
                .map(|b| Arc::new(b) as Arc<dyn Backend>)
                .map_err(|e| {
                    anyhow!(
                        "no usable backend for model {:?}: {e:#} \
                         (non-MLP models need the `pjrt` feature + `make artifacts`)",
                        meta.name
                    )
                })
        }
    }
}

/// Grad/eval execution for one model, behind whichever [`Backend`] the
/// run selected. Cheap to clone (the backend is shared).
#[derive(Clone)]
pub struct ModelRunner {
    backend: Arc<dyn Backend>,
    pub meta: ModelMeta,
    pub train_batch: usize,
    pub eval_batch: usize,
}

impl ModelRunner {
    /// Wrap an explicit backend (tests / custom embeddings).
    pub fn with_backend(
        backend: Arc<dyn Backend>,
        meta: ModelMeta,
        train_batch: usize,
        eval_batch: usize,
    ) -> Self {
        Self { backend, meta, train_batch, eval_batch }
    }

    /// Build the runner a [`RunConfig`] asks for: look the model up in
    /// the manifest and resolve the backend selection.
    pub fn for_config(manifest: &Manifest, cfg: &RunConfig) -> Result<Self> {
        let meta = manifest
            .model(&cfg.model)
            .ok_or_else(|| {
                anyhow!(
                    "model {:?} not in manifest (have: {})",
                    cfg.model,
                    manifest
                        .models
                        .iter()
                        .map(|m| m.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?
            .clone();
        let backend = resolve_backend(manifest, &meta, cfg)?;
        Ok(Self::with_backend(backend, meta, manifest.train_batch, manifest.eval_batch))
    }

    /// Which backend ended up selected.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// One grad step: returns `(loss, flat_grads)`.
    /// `x` is NHWC flattened (len = batch · prod(input)), `y` labels.
    pub fn grad(&self, params: &ParamVector, x: &[f32], y: &[i32]) -> Result<(f32, Vec<f32>)> {
        let b = self.train_batch;
        if y.len() != b {
            return Err(anyhow!("grad: expected batch {b}, got {}", y.len()));
        }
        self.backend.grad(params, x, y)
    }

    /// [`Self::grad`] into caller-owned scratch (see
    /// [`Backend::grad_into`]) — the round engine's hot path.
    pub fn grad_into(
        &self,
        params: &ParamVector,
        x: &[f32],
        y: &[i32],
        ws: &mut Workspace,
        grads: &mut Vec<f32>,
    ) -> Result<f32> {
        let b = self.train_batch;
        if y.len() != b {
            return Err(anyhow!("grad: expected batch {b}, got {}", y.len()));
        }
        self.backend.grad_into(params, x, y, ws, grads)
    }

    /// Eval one shard: returns `(loss_sum, correct_count)`.
    pub fn eval_shard(&self, params: &ParamVector, x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let b = self.eval_batch;
        if y.len() != b {
            return Err(anyhow!("eval: expected batch {b}, got {}", y.len()));
        }
        self.backend.eval_shard(params, x, y)
    }

    /// [`Self::eval_shard`] against caller-owned scratch.
    pub fn eval_into(
        &self,
        params: &ParamVector,
        x: &[f32],
        y: &[i32],
        ws: &mut Workspace,
    ) -> Result<(f32, f32)> {
        let b = self.eval_batch;
        if y.len() != b {
            return Err(anyhow!("eval: expected batch {b}, got {}", y.len()));
        }
        self.backend.eval_into(params, x, y, ws)
    }

    /// Evaluate over a whole dataset subset (loops eval-batch shards,
    /// truncating the tail so every shard is full; one workspace and
    /// one batch buffer serve every shard). Returns
    /// `(mean_loss, accuracy)`.
    pub fn evaluate(
        &self,
        params: &ParamVector,
        data: &crate::data::Dataset,
        max_samples: usize,
    ) -> Result<(f64, f64)> {
        let b = self.eval_batch;
        let n = data.len().min(max_samples) / b * b;
        if n == 0 {
            return Err(anyhow!("eval set smaller than one shard ({b})"));
        }
        let mut ws = Workspace::new();
        let mut idx: Vec<usize> = Vec::with_capacity(b);
        let mut x: Vec<f32> = Vec::new();
        let mut y: Vec<i32> = Vec::new();
        let mut loss_sum = 0f64;
        let mut correct = 0f64;
        for shard in 0..(n / b) {
            idx.clear();
            idx.extend(shard * b..(shard + 1) * b);
            data.batch_into(&idx, &mut x, &mut y);
            let (l, c) = self.eval_into(params, &x, &y, &mut ws)?;
            loss_sum += l as f64;
            correct += c as f64;
        }
        Ok((loss_sum / n as f64, correct / n as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::manifest::Manifest;

    #[test]
    fn kind_parses() {
        assert_eq!(BackendKind::parse("auto"), Some(BackendKind::Auto));
        assert_eq!(BackendKind::parse("native"), Some(BackendKind::Native));
        assert_eq!(BackendKind::parse("pjrt"), Some(BackendKind::Pjrt));
        assert_eq!(BackendKind::parse("tpu"), None);
        assert_eq!(BackendKind::default(), BackendKind::Auto);
    }

    /// Builtin manifest whose artifact paths point nowhere, so the
    /// tests behave identically whether or not `make artifacts` ran.
    fn artifactless_manifest() -> Manifest {
        let mut m = Manifest::builtin();
        m.dir = "/definitely/no/artifacts/here".into();
        m
    }

    #[test]
    fn for_config_falls_back_to_native() {
        let manifest = artifactless_manifest();
        let cfg = RunConfig::default();
        let runner = ModelRunner::for_config(&manifest, &cfg).unwrap();
        assert_eq!(runner.backend_name(), "native");
        assert_eq!(runner.meta.name, "mnist_mlp");
    }

    #[test]
    fn pjrt_without_feature_or_artifacts_errors() {
        let manifest = artifactless_manifest();
        let mut cfg = RunConfig::default();
        cfg.backend = BackendKind::Pjrt;
        assert!(ModelRunner::for_config(&manifest, &cfg).is_err());
    }

    #[test]
    fn unknown_model_reports_zoo() {
        let manifest = Manifest::builtin();
        let mut cfg = RunConfig::default();
        cfg.model = "alexnet".into();
        let err = ModelRunner::for_config(&manifest, &cfg).unwrap_err();
        assert!(format!("{err:#}").contains("mnist_mlp"));
    }
}
