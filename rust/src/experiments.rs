//! Shared experiment-harness helpers used by the `examples/` drivers
//! that regenerate the paper's tables and figures (DESIGN.md
//! per-experiment index E1-E8).

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::config::{Partition, RunConfig};
use crate::coordinator::{Algorithm, Trainer};
use crate::metrics::recorder::RunSummary;

/// Scale of an experiment run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// CI-sized: small synthetic corpus, few clients, short runs —
    /// minutes on a laptop; shapes (who wins) hold, constants shift.
    Quick,
    /// Paper-sized: 100 clients, full synthetic splits, long runs.
    Full,
}

impl Scale {
    pub fn from_args() -> Self {
        if std::env::args().any(|a| a == "--quick") {
            Scale::Quick
        } else {
            Scale::Full
        }
    }
}

/// Base experiment config at a given scale (paper §5 setting at Full).
pub fn base_config(model: &str, scale: Scale) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = model.to_string();
    cfg.dataset = if model.starts_with("cifar") { "cifar10" } else { "mnist" }.into();
    cfg.data_dir = Some(PathBuf::from("data"));
    match scale {
        Scale::Quick => {
            cfg.clients = 20;
            cfg.clients_per_round = 5;
            cfg.local_iters = 3;
            cfg.train_samples = Some(4_000);
            cfg.eval_samples = 1_000;
            cfg.rounds = 40;
            cfg.eval_every = 2;
        }
        Scale::Full => {
            cfg.clients = 100;
            cfg.clients_per_round = 10;
            cfg.local_iters = 5;
            cfg.eval_samples = 2_500;
            cfg.rounds = 150; // synthetic corpus converges by ~100
            cfg.eval_every = 5;
        }
    }
    cfg
}

/// Run one labeled configuration, appending its trace to `csv`.
/// Returns the run summary.
pub fn run_labeled(cfg: RunConfig, label: &str, csv: &Path) -> Result<RunSummary> {
    println!("── {label} ({} rounds) ──", cfg.rounds);
    cfg.validate().map_err(anyhow::Error::msg)?;
    let mut trainer = Trainer::new(cfg)?;
    trainer.recorder.label = label.to_string();
    let t0 = std::time::Instant::now();
    for round in 0..trainer.cfg.rounds {
        let out = trainer.run_round(round)?;
        if let Some((_, acc)) = out.eval {
            println!(
                "  round {:>4}: loss {:.4} acc {:.4}",
                round, out.mean_train_loss, acc
            );
        }
    }
    trainer.recorder.append_csv(csv)?;
    let s = trainer.recorder.summary();
    println!(
        "  → final acc {:.4}, upload {:.2} MB, {:.1}s wall\n",
        s.final_accuracy,
        s.total_up_bytes as f64 / 1e6,
        t0.elapsed().as_secs_f64()
    );
    Ok(s)
}

/// The paper's algorithm contenders for Fig. 3 at a given α.
pub fn fig3_contenders(alpha: f64) -> Vec<(String, Algorithm)> {
    use crate::sparse::thgs::ThgsConfig;
    vec![
        ("fedavg".into(), Algorithm::FedAvg),
        ("spark".into(), Algorithm::FlatSparse { s: 0.1 }),
        (
            format!("layerspares-a{alpha}"),
            Algorithm::Thgs(ThgsConfig { s0: 0.1, alpha, s_min: 0.01 }),
        ),
    ]
}

/// Partition setting helper.
pub fn with_partition(mut cfg: RunConfig, p: Partition) -> RunConfig {
    cfg.partition = p;
    cfg
}

/// Results directory (created on demand).
pub fn results_dir() -> PathBuf {
    let dir = PathBuf::from("results");
    let _ = std::fs::create_dir_all(&dir);
    dir
}
