//! Run configuration (DESIGN.md S18): defaults matching the paper's §5
//! experimental setup, INI-style config-file loading, and CLI overlay.

pub mod file;

use std::path::PathBuf;

use crate::coordinator::algorithms::Algorithm;
use crate::runtime::BackendKind;
use crate::sparse::thgs::ThgsConfig;

/// How training data is split across clients (§5's allocation matrix).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partition {
    Iid,
    /// Non-IID-n: each client holds exactly n label classes.
    NonIid(usize),
}

impl Partition {
    pub fn parse(s: &str) -> Option<Self> {
        if s == "iid" {
            return Some(Self::Iid);
        }
        // "noniid-4" / "non-iid-4"
        let tail = s.strip_prefix("noniid-").or_else(|| s.strip_prefix("non-iid-"))?;
        tail.parse().ok().map(Self::NonIid)
    }

    pub fn label(&self) -> String {
        match self {
            Self::Iid => "iid".into(),
            Self::NonIid(n) => format!("noniid-{n}"),
        }
    }
}

/// Which uplink carries the encoded payloads (the Collect barrier).
/// All three are conformance-pinned to identical payload bytes,
/// survivor sets, and metering (`tests/transport_conformance.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process function call — the deterministic-test twin.
    InProc,
    /// Framed TCP over localhost (ephemeral port).
    Tcp,
    /// Framed Unix-domain socket (unix only).
    Uds,
}

impl TransportKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "inproc" => Some(Self::InProc),
            "tcp" => Some(Self::Tcp),
            "uds" => Some(Self::Uds),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::InProc => "inproc",
            Self::Tcp => "tcp",
            Self::Uds => "uds",
        }
    }
}

/// Full run configuration. Defaults reproduce the paper's §5 setting:
/// 100 clients, 10 selected per round, 5 local iterations, batch 50.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: String,
    pub dataset: String,
    /// Compute backend: `Auto` picks PJRT when built with the `pjrt`
    /// feature and the AOT artifacts exist, native otherwise.
    pub backend: BackendKind,
    /// Directory probed for real datasets (falls back to synthetic).
    pub data_dir: Option<PathBuf>,
    pub artifacts_dir: PathBuf,
    /// Scale-down for CI runs: when Some(n), the synthetic train split
    /// has n samples (full split otherwise).
    pub train_samples: Option<usize>,
    pub eval_samples: usize,

    pub clients: usize,
    pub clients_per_round: usize,
    pub local_iters: usize,
    pub lr: f32,
    pub rounds: u64,
    pub eval_every: u64,
    pub partition: Partition,
    pub seed: u64,

    pub algorithm: Algorithm,
    /// Wrap updates in mask-sparsified secure aggregation (§3.2).
    pub secure: bool,
    /// Test/verification aid: in secure mode, also accumulate each
    /// client's *unmasked* contribution server-side so tests can
    /// assert the masks cancel. Never enable outside a harness — it
    /// reveals exactly what the protocol exists to hide.
    pub audit_secure_sum: bool,
    /// Test/harness observability: copy each round's server-side
    /// aggregate into [`RoundOutcome::aggregate`]. Off by default —
    /// the copy is the one model-sized allocation the steady-state
    /// coordinator path would otherwise make per round (the aggregate
    /// itself lives in the trainer-owned `ServerWorkspace`).
    ///
    /// [`RoundOutcome::aggregate`]: crate::coordinator::RoundOutcome
    pub expose_aggregate: bool,
    /// Eq. 4 mask keep-ratio numerator k (secure mode).
    pub mask_ratio_k: f64,
    /// Secure-mode pair-mask topology: each client masks against a
    /// seeded k-regular neighborhood of ~`neighbors_k` peers instead
    /// of the full cohort. 0 (default) = complete graph (every pair),
    /// which is bitwise-identical to the pre-neighborhood behavior.
    /// Values ≥ cohort−1 also collapse to the complete graph.
    pub neighbors_k: usize,
    /// Coordinator aggregation shards: Collect streams each uplink
    /// into a range-sharded accumulator with this many folders. Any
    /// value reproduces the serial sum bit-for-bit (shards partition
    /// coordinates, and merge is a copy in ascending shard order).
    pub shards: usize,
    /// Eq. 2 dynamic sparsity-rate controller (secure / THGS modes).
    pub dynamic_rate: bool,
    pub rate_alpha: f64,
    pub rate_min: f64,
    /// QSGD-style stochastic value quantization (§2.1 extension;
    /// non-secure modes only — quantizing masked values would break
    /// pairwise cancellation).
    pub quant_bits: Option<u8>,
    /// DGC momentum-correction coefficient (0.0 = off; §6 future work).
    pub momentum: f32,
    /// DGC warm-up rounds: sparsity relaxed dense→target (0 = off).
    pub warmup_rounds: u64,

    /// Per-round probability a selected client crashes before its
    /// upload arrives (transport failure injection; 0.0 = off). In
    /// secure mode, enabling this switches setup to Shamir-share the
    /// pair keys so the server can recover dropped clients' masks —
    /// O(n³) share material, sized for per-round cohorts, not huge
    /// fleets.
    pub dropout_prob: f64,
    /// Server-side collect deadline in *simulated* seconds: uploads
    /// arriving later are excluded from the round (stragglers).
    /// `f64::INFINITY` = no deadline.
    pub straggler_timeout_s: f64,
    /// Abort the round (no model update; clients roll back, residuals
    /// carry forward) when fewer uploads than this arrive.
    pub min_survivors: usize,

    /// Which uplink carries the Collect barrier.
    pub transport: TransportKind,
    /// Chaos: per-attempt packet-loss probability (`[0,1)`; a frame
    /// losing all retries never arrives → the client is dropped).
    pub chaos_loss: f64,
    /// Chaos: frame-duplication probability (server dedups by cid).
    pub chaos_dup: f64,
    /// Chaos: out-of-order-arrival probability (the resequencing fold
    /// restores ascending-cid order — never changes the aggregate).
    pub chaos_reorder: f64,
    /// Chaos: slow-link probability (delivery time × factor below).
    pub chaos_slow: f64,
    /// Delivery-time multiplier for slow links (≥ 1).
    pub chaos_slow_factor: f64,
    /// Retransmission attempts after a lost one.
    pub chaos_retries: u32,
    /// Socket transports: real-time hang backstop per Collect barrier
    /// (milliseconds). Straggler classification stays simulated-time;
    /// this only bounds genuine wedges.
    pub socket_deadline_ms: u64,

    /// PJRT executor threads.
    pub exec_workers: usize,
    /// Client-side worker threads (sparsify/mask/encode).
    pub client_workers: usize,

    /// Durable runs: directory for end-of-round checkpoints
    /// (`io::checkpoint`). None (default) = no checkpointing.
    pub checkpoint_dir: Option<PathBuf>,
    /// Commit a checkpoint every N successfully applied rounds (the
    /// final round always commits). Must be ≥ 1 when checkpointing.
    pub checkpoint_every: u64,
    /// Resume from the newest valid checkpoint in `checkpoint_dir`
    /// instead of starting fresh (falls back to a fresh start, loudly,
    /// when no valid checkpoint exists).
    pub resume: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            model: "mnist_mlp".into(),
            dataset: "mnist".into(),
            backend: BackendKind::Auto,
            data_dir: Some(PathBuf::from("data")),
            artifacts_dir: PathBuf::from("artifacts"),
            train_samples: None,
            eval_samples: 2_500,
            clients: 100,
            clients_per_round: 10,
            local_iters: 5,
            lr: 0.1,
            rounds: 100,
            eval_every: 5,
            partition: Partition::Iid,
            seed: 42,
            algorithm: Algorithm::Thgs(ThgsConfig::default()),
            secure: false,
            audit_secure_sum: false,
            expose_aggregate: false,
            mask_ratio_k: 1.0,
            neighbors_k: 0,
            shards: 1,
            dynamic_rate: false,
            rate_alpha: 0.8,
            rate_min: 0.01,
            quant_bits: None,
            momentum: 0.0,
            warmup_rounds: 0,
            dropout_prob: 0.0,
            straggler_timeout_s: f64::INFINITY,
            min_survivors: 1,
            transport: TransportKind::InProc,
            chaos_loss: 0.0,
            chaos_dup: 0.0,
            chaos_reorder: 0.0,
            chaos_slow: 0.0,
            chaos_slow_factor: 4.0,
            chaos_retries: 3,
            socket_deadline_ms: 5_000,
            exec_workers: 4,
            client_workers: 4,
            checkpoint_dir: None,
            checkpoint_every: 1,
            resume: false,
        }
    }
}

impl RunConfig {
    /// A small, fast configuration for tests: few clients, small
    /// synthetic corpus, few rounds.
    pub fn smoke(model: &str) -> Self {
        Self {
            model: model.into(),
            dataset: if model.starts_with("cifar") { "cifar10" } else { "mnist" }.into(),
            train_samples: Some(2_000),
            eval_samples: 500,
            clients: 10,
            clients_per_round: 4,
            local_iters: 2,
            rounds: 6,
            eval_every: 2,
            exec_workers: 2,
            client_workers: 2,
            ..Self::default()
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.clients_per_round == 0 || self.clients_per_round > self.clients {
            return Err(format!(
                "clients_per_round {} outside [1, {}]",
                self.clients_per_round, self.clients
            ));
        }
        if self.secure && self.clients_per_round < 2 {
            return Err("secure aggregation needs ≥2 clients per round".into());
        }
        if self.rounds == 0 {
            return Err("rounds must be ≥ 1".into());
        }
        if self.backend == BackendKind::Pjrt && !cfg!(feature = "pjrt") {
            return Err("backend pjrt requires building with `--features pjrt`".into());
        }
        if self.audit_secure_sum && !self.secure {
            return Err("audit_secure_sum only makes sense with secure aggregation on".into());
        }
        if let Algorithm::Thgs(t) = &self.algorithm {
            t.validate()?;
        }
        if self.secure && self.quant_bits.is_some() {
            return Err("quantization is incompatible with secure masking".into());
        }
        if let Some(b) = self.quant_bits {
            if !(2..=8).contains(&b) {
                return Err(format!("quant_bits {b} outside 2..=8"));
            }
        }
        if self.shards == 0 {
            return Err("shards must be ≥ 1".into());
        }
        if !(0.0..1.0).contains(&self.momentum) {
            return Err(format!("momentum {} outside [0,1)", self.momentum));
        }
        if !(0.0..1.0).contains(&self.dropout_prob) {
            return Err(format!("dropout_prob {} outside [0,1)", self.dropout_prob));
        }
        if self.straggler_timeout_s <= 0.0 || self.straggler_timeout_s.is_nan() {
            return Err(format!(
                "straggler_timeout_s {} must be positive (use infinity for none)",
                self.straggler_timeout_s
            ));
        }
        if self.min_survivors == 0 || self.min_survivors > self.clients_per_round {
            return Err(format!(
                "min_survivors {} outside [1, {}]",
                self.min_survivors, self.clients_per_round
            ));
        }
        if self.secure && self.failure_injection() && self.min_survivors < 2 {
            return Err(
                "secure mode with failure injection needs min_survivors ≥ 2 \
                 (mask recovery requires a surviving pair)"
                    .into(),
            );
        }
        for (name, p) in [
            ("chaos_loss", self.chaos_loss),
            ("chaos_dup", self.chaos_dup),
            ("chaos_reorder", self.chaos_reorder),
            ("chaos_slow", self.chaos_slow),
        ] {
            if !(0.0..1.0).contains(&p) {
                return Err(format!("{name} {p} outside [0,1)"));
            }
        }
        if self.chaos_slow_factor < 1.0 || !self.chaos_slow_factor.is_finite() {
            return Err(format!("chaos_slow_factor {} must be ≥ 1", self.chaos_slow_factor));
        }
        if self.transport == TransportKind::Uds && !cfg!(unix) {
            return Err("transport uds requires a unix platform".into());
        }
        if self.socket_deadline_ms == 0 {
            return Err("socket_deadline_ms must be ≥ 1".into());
        }
        if self.resume && self.checkpoint_dir.is_none() {
            return Err(
                "--resume needs --checkpoint-dir: resuming means loading the newest \
                 checkpoint from that directory (and new checkpoints keep landing there)"
                    .into(),
            );
        }
        if self.checkpoint_dir.is_some() && self.checkpoint_every == 0 {
            return Err(
                "checkpoint_every must be ≥ 1 when --checkpoint-dir is set \
                 (1 = commit after every applied round)"
                    .into(),
            );
        }
        Ok(())
    }

    /// Is transport failure injection (dropout, straggler deadline,
    /// and/or chaos loss — everything that can remove a client from
    /// the round) live for this run? Gates rollback snapshots and, in
    /// secure mode, Shamir share material for mask recovery.
    pub fn failure_injection(&self) -> bool {
        self.dropout_prob > 0.0 || self.straggler_timeout_s.is_finite() || self.chaos_loss > 0.0
    }

    /// Short label for metric files: `thgs-s0.1-noniid-4` etc.
    pub fn run_label(&self) -> String {
        let alg = self.algorithm.label();
        let sec = if self.secure { "-secure" } else { "" };
        format!("{}-{}-{}{}", self.model, alg, self.partition.label(), sec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_setup() {
        let c = RunConfig::default();
        assert_eq!(c.clients, 100);
        assert_eq!(c.clients_per_round, 10);
        assert_eq!(c.local_iters, 5);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn partition_parsing() {
        assert_eq!(Partition::parse("iid"), Some(Partition::Iid));
        assert_eq!(Partition::parse("noniid-4"), Some(Partition::NonIid(4)));
        assert_eq!(Partition::parse("non-iid-8"), Some(Partition::NonIid(8)));
        assert_eq!(Partition::parse("bogus"), None);
    }

    #[test]
    fn validation_catches_bad_selection() {
        let mut c = RunConfig::default();
        c.clients_per_round = 0;
        assert!(c.validate().is_err());
        c.clients_per_round = 1000;
        assert!(c.validate().is_err());
    }

    #[test]
    fn secure_needs_two() {
        let mut c = RunConfig::default();
        c.secure = true;
        c.clients_per_round = 1;
        assert!(c.validate().is_err());
    }

    #[test]
    fn pjrt_backend_needs_feature() {
        let mut c = RunConfig::default();
        c.backend = BackendKind::Pjrt;
        assert_eq!(c.validate().is_ok(), cfg!(feature = "pjrt"));
    }

    #[test]
    fn audit_requires_secure() {
        let mut c = RunConfig::default();
        c.audit_secure_sum = true;
        assert!(c.validate().is_err());
        c.secure = true;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn failure_injection_knobs_validate() {
        let mut c = RunConfig::default();
        assert!(!c.failure_injection());
        c.dropout_prob = 0.2;
        assert!(c.failure_injection());
        assert!(c.validate().is_ok());
        c.dropout_prob = 1.0;
        assert!(c.validate().is_err(), "certain dropout rejected");
        c.dropout_prob = 0.2;
        c.min_survivors = 0;
        assert!(c.validate().is_err());
        c.min_survivors = c.clients_per_round + 1;
        assert!(c.validate().is_err());
        c.min_survivors = 1;
        c.straggler_timeout_s = 0.0;
        assert!(c.validate().is_err());
        c.straggler_timeout_s = 2.5;
        assert!(c.failure_injection());
        assert!(c.validate().is_ok());
    }

    #[test]
    fn secure_dropout_needs_surviving_pair() {
        let mut c = RunConfig::default();
        c.secure = true;
        c.dropout_prob = 0.1;
        c.min_survivors = 1;
        assert!(c.validate().is_err());
        c.min_survivors = 2;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn topology_and_shard_knobs_validate() {
        let c = RunConfig::default();
        assert_eq!(c.neighbors_k, 0, "default is the complete pair graph");
        assert_eq!(c.shards, 1, "default is a single aggregation shard");
        let mut c = RunConfig::default();
        c.shards = 0;
        assert!(c.validate().is_err());
        c.shards = 8;
        c.neighbors_k = 12;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn transport_parsing() {
        assert_eq!(TransportKind::parse("inproc"), Some(TransportKind::InProc));
        assert_eq!(TransportKind::parse("tcp"), Some(TransportKind::Tcp));
        assert_eq!(TransportKind::parse("uds"), Some(TransportKind::Uds));
        assert_eq!(TransportKind::parse("quic"), None);
        assert_eq!(TransportKind::Tcp.label(), "tcp");
    }

    #[test]
    fn chaos_knobs_validate() {
        let mut c = RunConfig::default();
        c.chaos_loss = 0.3;
        assert!(c.validate().is_ok());
        assert!(c.failure_injection(), "chaos loss can remove clients");
        c.chaos_loss = 1.0;
        assert!(c.validate().is_err(), "certain loss rejected");
        c.chaos_loss = 0.0;
        assert!(!c.failure_injection());
        c.chaos_reorder = -0.1;
        assert!(c.validate().is_err());
        c.chaos_reorder = 0.5;
        c.chaos_slow_factor = 0.5;
        assert!(c.validate().is_err(), "slow factor below 1 rejected");
        c.chaos_slow_factor = 4.0;
        assert!(c.validate().is_ok());
        c.socket_deadline_ms = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn secure_chaos_loss_needs_surviving_pair() {
        let mut c = RunConfig::default();
        c.secure = true;
        c.chaos_loss = 0.2;
        c.min_survivors = 1;
        assert!(c.validate().is_err(), "chaos loss counts as failure injection");
        c.min_survivors = 2;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn checkpoint_knobs_validate_with_actionable_errors() {
        let mut c = RunConfig::default();
        c.resume = true;
        let err = c.validate().expect_err("--resume without --checkpoint-dir must be rejected");
        assert!(err.contains("--checkpoint-dir"), "unhelpful error: {err}");
        c.checkpoint_dir = Some(PathBuf::from("/tmp/ckpt"));
        assert!(c.validate().is_ok());
        c.checkpoint_every = 0;
        let err = c.validate().expect_err("checkpoint_every=0 must be rejected");
        assert!(err.contains("checkpoint_every"), "unhelpful error: {err}");
        c.checkpoint_every = 5;
        assert!(c.validate().is_ok());
        // checkpoint_every is only meaningful with a checkpoint dir;
        // 0 without one validates (nothing will ever be committed).
        let mut c = RunConfig::default();
        c.checkpoint_every = 0;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn label_is_stable() {
        let c = RunConfig::default();
        assert!(c.run_label().contains("mnist_mlp"));
        assert!(c.run_label().contains("iid"));
    }
}
