//! INI-style config file support: `key = value` lines, `#`/`;`
//! comments, optional `[section]` headers flattened to `section.key`.
//! Used by `fedsparse train --config run.ini`; CLI flags override.
//!
//! [`to_map`] / [`apply_map`] round-trip a full [`RunConfig`] through
//! the flat string map — the same representation the checkpoint
//! layer's `config_digest` hashes and a run manifest embeds, so "the
//! config a run used" has exactly one serialized form.

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::config::{Partition, RunConfig, TransportKind};
use crate::coordinator::algorithms::Algorithm;
use crate::runtime::BackendKind;

#[derive(Debug, thiserror::Error)]
pub enum ConfigFileError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("line {0}: expected 'key = value'")]
    BadLine(usize),
}

/// Parse INI text to a flat `section.key → value` map.
pub fn parse(text: &str) -> Result<BTreeMap<String, String>, ConfigFileError> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line.split_once('=').ok_or(ConfigFileError::BadLine(lineno + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        // strip trailing comments and quotes
        let mut val = v.trim();
        if let Some(i) = val.find(" #") {
            val = val[..i].trim();
        }
        let val = val.trim_matches('"').to_string();
        out.insert(key, val);
    }
    Ok(out)
}

/// Load and parse a config file.
pub fn load(path: &std::path::Path) -> Result<BTreeMap<String, String>, ConfigFileError> {
    parse(&std::fs::read_to_string(path)?)
}

/// Serialize every [`RunConfig`] field to the flat string map.
/// Conventions: optional paths serialize as `""` = None, optional
/// counts as `0` = None, `straggler_timeout_s` uses `inf` for no
/// deadline, and the algorithm uses its parseable
/// [`Algorithm::spec`] form. [`apply_map`] inverts all of them.
pub fn to_map(cfg: &RunConfig) -> BTreeMap<String, String> {
    let mut m = BTreeMap::new();
    let path_str = |p: &Option<PathBuf>| {
        p.as_ref().map(|p| p.to_string_lossy().into_owned()).unwrap_or_default()
    };
    m.insert("model".into(), cfg.model.clone());
    m.insert("dataset".into(), cfg.dataset.clone());
    m.insert("backend".into(), cfg.backend.label().to_string());
    m.insert("data_dir".into(), path_str(&cfg.data_dir));
    m.insert("artifacts_dir".into(), cfg.artifacts_dir.to_string_lossy().into_owned());
    m.insert("train_samples".into(), cfg.train_samples.unwrap_or(0).to_string());
    m.insert("eval_samples".into(), cfg.eval_samples.to_string());
    m.insert("clients".into(), cfg.clients.to_string());
    m.insert("clients_per_round".into(), cfg.clients_per_round.to_string());
    m.insert("local_iters".into(), cfg.local_iters.to_string());
    m.insert("lr".into(), cfg.lr.to_string());
    m.insert("rounds".into(), cfg.rounds.to_string());
    m.insert("eval_every".into(), cfg.eval_every.to_string());
    m.insert("partition".into(), cfg.partition.label());
    m.insert("seed".into(), cfg.seed.to_string());
    m.insert("algorithm".into(), cfg.algorithm.spec());
    m.insert("secure".into(), cfg.secure.to_string());
    m.insert("audit_secure_sum".into(), cfg.audit_secure_sum.to_string());
    m.insert("expose_aggregate".into(), cfg.expose_aggregate.to_string());
    m.insert("mask_ratio_k".into(), cfg.mask_ratio_k.to_string());
    m.insert("neighbors_k".into(), cfg.neighbors_k.to_string());
    m.insert("shards".into(), cfg.shards.to_string());
    m.insert("dynamic_rate".into(), cfg.dynamic_rate.to_string());
    m.insert("rate_alpha".into(), cfg.rate_alpha.to_string());
    m.insert("rate_min".into(), cfg.rate_min.to_string());
    m.insert("quant_bits".into(), cfg.quant_bits.unwrap_or(0).to_string());
    m.insert("momentum".into(), cfg.momentum.to_string());
    m.insert("warmup_rounds".into(), cfg.warmup_rounds.to_string());
    m.insert("dropout_prob".into(), cfg.dropout_prob.to_string());
    m.insert("straggler_timeout_s".into(), cfg.straggler_timeout_s.to_string());
    m.insert("min_survivors".into(), cfg.min_survivors.to_string());
    m.insert("transport".into(), cfg.transport.label().to_string());
    m.insert("chaos_loss".into(), cfg.chaos_loss.to_string());
    m.insert("chaos_dup".into(), cfg.chaos_dup.to_string());
    m.insert("chaos_reorder".into(), cfg.chaos_reorder.to_string());
    m.insert("chaos_slow".into(), cfg.chaos_slow.to_string());
    m.insert("chaos_slow_factor".into(), cfg.chaos_slow_factor.to_string());
    m.insert("chaos_retries".into(), cfg.chaos_retries.to_string());
    m.insert("socket_deadline_ms".into(), cfg.socket_deadline_ms.to_string());
    m.insert("exec_workers".into(), cfg.exec_workers.to_string());
    m.insert("client_workers".into(), cfg.client_workers.to_string());
    m.insert("checkpoint_dir".into(), path_str(&cfg.checkpoint_dir));
    m.insert("checkpoint_every".into(), cfg.checkpoint_every.to_string());
    m.insert("resume".into(), cfg.resume.to_string());
    m
}

/// Overlay a parsed map onto a config. Every key [`to_map`] emits is
/// accepted; unknown keys and unparseable values are errors naming
/// the offending key.
pub fn apply_map(cfg: &mut RunConfig, map: &BTreeMap<String, String>) -> Result<(), String> {
    fn bad(key: &str, val: &str) -> String {
        format!("config key {key:?}: cannot parse value {val:?}")
    }
    fn parse_num<T: std::str::FromStr>(key: &str, val: &str) -> Result<T, String> {
        val.parse().map_err(|_| bad(key, val))
    }
    fn parse_bool(key: &str, val: &str) -> Result<bool, String> {
        match val {
            "true" | "1" | "yes" => Ok(true),
            "false" | "0" | "no" => Ok(false),
            _ => Err(bad(key, val)),
        }
    }
    for (k, v) in map {
        match k.as_str() {
            "model" => cfg.model = v.clone(),
            "dataset" => cfg.dataset = v.clone(),
            "backend" => cfg.backend = BackendKind::parse(v).ok_or_else(|| bad(k, v))?,
            "data_dir" => {
                cfg.data_dir = if v.is_empty() { None } else { Some(PathBuf::from(v)) }
            }
            "artifacts_dir" => cfg.artifacts_dir = PathBuf::from(v),
            "train_samples" => {
                let n: usize = parse_num(k, v)?;
                cfg.train_samples = if n == 0 { None } else { Some(n) };
            }
            "eval_samples" => cfg.eval_samples = parse_num(k, v)?,
            "clients" => cfg.clients = parse_num(k, v)?,
            "clients_per_round" => cfg.clients_per_round = parse_num(k, v)?,
            "local_iters" => cfg.local_iters = parse_num(k, v)?,
            "lr" => cfg.lr = parse_num(k, v)?,
            "rounds" => cfg.rounds = parse_num(k, v)?,
            "eval_every" => cfg.eval_every = parse_num(k, v)?,
            "partition" => cfg.partition = Partition::parse(v).ok_or_else(|| bad(k, v))?,
            "seed" => cfg.seed = parse_num(k, v)?,
            "algorithm" => cfg.algorithm = Algorithm::parse(v).ok_or_else(|| bad(k, v))?,
            "secure" => cfg.secure = parse_bool(k, v)?,
            "audit_secure_sum" => cfg.audit_secure_sum = parse_bool(k, v)?,
            "expose_aggregate" => cfg.expose_aggregate = parse_bool(k, v)?,
            "mask_ratio_k" => cfg.mask_ratio_k = parse_num(k, v)?,
            "neighbors_k" => cfg.neighbors_k = parse_num(k, v)?,
            "shards" => cfg.shards = parse_num(k, v)?,
            "dynamic_rate" => cfg.dynamic_rate = parse_bool(k, v)?,
            "rate_alpha" => cfg.rate_alpha = parse_num(k, v)?,
            "rate_min" => cfg.rate_min = parse_num(k, v)?,
            "quant_bits" => {
                let b: u8 = parse_num(k, v)?;
                cfg.quant_bits = if b == 0 { None } else { Some(b) };
            }
            "momentum" => cfg.momentum = parse_num(k, v)?,
            "warmup_rounds" => cfg.warmup_rounds = parse_num(k, v)?,
            "dropout_prob" => cfg.dropout_prob = parse_num(k, v)?,
            "straggler_timeout_s" => cfg.straggler_timeout_s = parse_num(k, v)?,
            "min_survivors" => cfg.min_survivors = parse_num(k, v)?,
            "transport" => cfg.transport = TransportKind::parse(v).ok_or_else(|| bad(k, v))?,
            "chaos_loss" => cfg.chaos_loss = parse_num(k, v)?,
            "chaos_dup" => cfg.chaos_dup = parse_num(k, v)?,
            "chaos_reorder" => cfg.chaos_reorder = parse_num(k, v)?,
            "chaos_slow" => cfg.chaos_slow = parse_num(k, v)?,
            "chaos_slow_factor" => cfg.chaos_slow_factor = parse_num(k, v)?,
            "chaos_retries" => cfg.chaos_retries = parse_num(k, v)?,
            "socket_deadline_ms" => cfg.socket_deadline_ms = parse_num(k, v)?,
            "exec_workers" => cfg.exec_workers = parse_num(k, v)?,
            "client_workers" => cfg.client_workers = parse_num(k, v)?,
            "checkpoint_dir" => {
                cfg.checkpoint_dir = if v.is_empty() { None } else { Some(PathBuf::from(v)) }
            }
            "checkpoint_every" => cfg.checkpoint_every = parse_num(k, v)?,
            "resume" => cfg.resume = parse_bool(k, v)?,
            _ => return Err(format!("unknown config key {k:?}")),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let text = r#"
# comment
model = mnist_mlp
rounds = 100

[sparsity]
s0 = 0.1      # inline comment
alpha = 0.8
; another comment
label = "quoted value"
"#;
        let m = parse(text).unwrap();
        assert_eq!(m["model"], "mnist_mlp");
        assert_eq!(m["rounds"], "100");
        assert_eq!(m["sparsity.s0"], "0.1");
        assert_eq!(m["sparsity.alpha"], "0.8");
        assert_eq!(m["sparsity.label"], "quoted value");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(matches!(parse("not a kv line"), Err(ConfigFileError::BadLine(1))));
    }

    #[test]
    fn empty_ok() {
        assert!(parse("").unwrap().is_empty());
    }

    #[test]
    fn run_config_round_trips_through_map() {
        use crate::sparse::thgs::ThgsConfig;
        let mut cfg = RunConfig::default();
        cfg.model = "mnist_mlp".into();
        cfg.backend = BackendKind::Native;
        cfg.data_dir = None;
        cfg.train_samples = Some(2_000);
        cfg.lr = 0.05;
        cfg.algorithm = Algorithm::Thgs(ThgsConfig { s0: 0.2, alpha: 0.55, s_min: 0.015 });
        cfg.partition = Partition::NonIid(4);
        cfg.secure = true;
        cfg.neighbors_k = 3;
        cfg.quant_bits = None;
        cfg.dropout_prob = 0.25;
        cfg.min_survivors = 2;
        cfg.transport = TransportKind::Tcp;
        cfg.checkpoint_dir = Some(PathBuf::from("/tmp/run/ckpt"));
        cfg.checkpoint_every = 3;
        cfg.resume = true;
        let map = to_map(&cfg);
        assert_eq!(map["straggler_timeout_s"], "inf", "no-deadline form is parseable");
        assert_eq!(map["checkpoint_dir"], "/tmp/run/ckpt");
        assert_eq!(map["resume"], "true");
        let mut restored = RunConfig::default();
        apply_map(&mut restored, &map).unwrap();
        assert_eq!(to_map(&restored), map, "to_map ∘ apply_map must be the identity");
        assert!(restored.straggler_timeout_s.is_infinite());
        assert_eq!(restored.checkpoint_dir, Some(PathBuf::from("/tmp/run/ckpt")));
        assert_eq!(restored.checkpoint_every, 3);
        assert!(restored.resume);
    }

    #[test]
    fn apply_map_rejects_unknown_keys_and_bad_values() {
        let mut cfg = RunConfig::default();
        let mut map = BTreeMap::new();
        map.insert("no_such_knob".to_string(), "1".to_string());
        let err = apply_map(&mut cfg, &map).unwrap_err();
        assert!(err.contains("no_such_knob"), "unhelpful error: {err}");
        let mut map = BTreeMap::new();
        map.insert("checkpoint_every".to_string(), "often".to_string());
        let err = apply_map(&mut cfg, &map).unwrap_err();
        assert!(err.contains("checkpoint_every"), "unhelpful error: {err}");
    }
}
