//! INI-style config file support: `key = value` lines, `#`/`;`
//! comments, optional `[section]` headers flattened to `section.key`.
//! Used by `fedsparse train --config run.ini`; CLI flags override.

use std::collections::BTreeMap;

#[derive(Debug, thiserror::Error)]
pub enum ConfigFileError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("line {0}: expected 'key = value'")]
    BadLine(usize),
}

/// Parse INI text to a flat `section.key → value` map.
pub fn parse(text: &str) -> Result<BTreeMap<String, String>, ConfigFileError> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line.split_once('=').ok_or(ConfigFileError::BadLine(lineno + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        // strip trailing comments and quotes
        let mut val = v.trim();
        if let Some(i) = val.find(" #") {
            val = val[..i].trim();
        }
        let val = val.trim_matches('"').to_string();
        out.insert(key, val);
    }
    Ok(out)
}

/// Load and parse a config file.
pub fn load(path: &std::path::Path) -> Result<BTreeMap<String, String>, ConfigFileError> {
    parse(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let text = r#"
# comment
model = mnist_mlp
rounds = 100

[sparsity]
s0 = 0.1      # inline comment
alpha = 0.8
; another comment
label = "quoted value"
"#;
        let m = parse(text).unwrap();
        assert_eq!(m["model"], "mnist_mlp");
        assert_eq!(m["rounds"], "100");
        assert_eq!(m["sparsity.s0"], "0.1");
        assert_eq!(m["sparsity.alpha"], "0.8");
        assert_eq!(m["sparsity.label"], "quoted value");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(matches!(parse("not a kv line"), Err(ConfigFileError::BadLine(1))));
    }

    #[test]
    fn empty_ok() {
        assert!(parse("").unwrap().is_empty());
    }
}
