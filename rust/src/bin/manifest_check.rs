//! `manifest_check` — emit and validate schema-versioned run
//! manifests (see [`fedsparse::io::manifest`]).
//!
//! Two modes, composable in one invocation:
//!
//! * `--emit-dir DIR` builds a sealed directory manifest over `DIR`
//!   (sorted scan, `--match` prefix filter, debris skipped), writes it
//!   atomically, then validates what it just wrote.
//! * `--check a.json,b.json` validates existing manifest files:
//!   schema version, canonical `manifest_sha256`, and every named
//!   artifact's existence/size/sha256.
//!
//! Exit codes mirror `bench_diff`: 0 = all manifests valid, 1 =
//! validation failures, 2 = infrastructure error (unreadable
//! directory, bad flags).
//!
//! ```text
//! manifest_check --emit-dir bench-history --kind bench-history \
//!     --run-id nightly-$SHA --meta commit=$SHA,toolchain=stable
//! manifest_check --check results/run.csv.manifest.json
//! ```

use std::path::PathBuf;
use std::process::ExitCode;

use fedsparse::io::manifest::{directory_manifest, validate_manifest_file, write_manifest};
use fedsparse::util::cli::{ArgSpec, Args, CliError};
use fedsparse::util::json::{s, Value};

const SPEC: &[ArgSpec] = &[
    ArgSpec::opt("check", "c", "", "comma-separated manifest files to validate"),
    ArgSpec::opt("emit-dir", "e", "", "build + write a directory manifest over this dir"),
    ArgSpec::opt("out", "o", "", "emitted manifest path (default: <emit-dir>/MANIFEST.json)"),
    ArgSpec::opt("kind", "", "directory", "manifest kind tag (e.g. bench-history, bench-run)"),
    ArgSpec::opt("match", "", "", "emit: only include files whose name starts with this prefix"),
    ArgSpec::opt("run-id", "", "manual", "run identifier recorded in the manifest"),
    ArgSpec::opt("meta", "", "", "extra metadata, k=v[,k=v...] (values recorded as strings)"),
];

fn main() -> ExitCode {
    let args = match Args::parse_spec("manifest_check", SPEC, std::env::args().skip(1)) {
        Ok(a) => a,
        Err(CliError::Help) => return ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::from(2);
        }
    };
    match run(&args) {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::from(2)
        }
    }
}

/// `Ok(true)` = everything validated, `Ok(false)` = validation
/// failures (exit 1), `Err` = infra (exit 2).
fn run(args: &Args) -> anyhow::Result<bool> {
    let emit_dir = args.get("emit-dir").unwrap_or("");
    let check = args.get("check").unwrap_or("");
    if emit_dir.is_empty() && check.is_empty() {
        anyhow::bail!("nothing to do: pass --emit-dir and/or --check (see --help)");
    }

    let mut all_valid = true;
    let mut to_check: Vec<PathBuf> = check
        .split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(PathBuf::from)
        .collect();

    if !emit_dir.is_empty() {
        let dir = PathBuf::from(emit_dir);
        let meta: Vec<(String, Value)> = args
            .get("meta")
            .unwrap_or("")
            .split(',')
            .filter_map(|kv| kv.split_once('='))
            .map(|(k, v)| (k.trim().to_string(), s(v.trim())))
            .collect();
        let built = directory_manifest(
            &dir,
            args.get("kind").unwrap_or("directory"),
            args.get("run-id").unwrap_or("manual"),
            args.get("match").unwrap_or(""),
            meta,
        )
        .map_err(|e| anyhow::anyhow!("scan {dir:?}: {e}"))?;
        for (p, why) in &built.invalid {
            eprintln!("warning: skipped unreadable artifact {p}: {why}");
        }
        let out = match args.get("out").unwrap_or("") {
            "" => dir.join("MANIFEST.json"),
            explicit => PathBuf::from(explicit),
        };
        write_manifest(&out, &built.manifest)
            .map_err(|e| anyhow::anyhow!("write {out:?}: {e}"))?;
        let n = built
            .manifest
            .get("artifacts")
            .and_then(|a| a.as_array())
            .map(|a| a.len())
            .unwrap_or(0);
        println!("emitted {} ({n} artifacts)", out.display());
        to_check.push(out);
    }

    for path in &to_check {
        let issues = validate_manifest_file(path);
        if issues.is_empty() {
            println!("OK    {}", path.display());
        } else {
            all_valid = false;
            println!("FAIL  {}", path.display());
            for issue in issues {
                println!("      - {issue}");
            }
        }
    }
    Ok(all_valid)
}
