//! `bench_diff` — the CI perf-regression gate.
//!
//! Compares freshly produced `BENCH_*.json` reports (from `cargo
//! bench`, quick mode in CI) against the committed baselines under
//! `bench-history/`, with tolerance bands: exit 1 when any case's p50
//! regresses more than `--fail-pct`, print warnings above `--warn-pct`
//! (see `util::benchcmp` for the banding rules and PERF.md for how to
//! read the bands). A markdown summary — full p50/p95 table per report
//! — is printed and, with `--summary`, appended to a file (CI passes
//! `$GITHUB_STEP_SUMMARY`).
//!
//! Bootstrap behavior: reports with no committed baseline are listed
//! (current numbers only) and never fail, so the gate is safe to wire
//! up before the first baselines land; when *nothing* was compared the
//! headline says "reporting-only" explicitly rather than a vacuous
//! "ok" over zero cases. `--inflate-current <pct>`
//! scales the current numbers up before comparing — CI's self-test
//! uses it to prove a synthetic >30% regression actually trips the
//! gate.
//!
//! ```text
//! cargo run --release --bin bench_diff -- \
//!     --baseline-dir ../bench-history --current-dir . \
//!     --fail-pct 30 --warn-pct 15 --summary "$GITHUB_STEP_SUMMARY"
//! ```

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use fedsparse::util::benchcmp::{
    compare, inflate_report, markdown, markdown_current_only, markdown_reporting_only, worst,
    BenchComparison, Tolerance, Verdict,
};
use fedsparse::util::cli::{ArgSpec, Args, CliError};
use fedsparse::util::json;

const SPEC: &[ArgSpec] = &[
    ArgSpec::opt("baseline-dir", "b", "../bench-history", "committed baseline BENCH_*.json directory"),
    ArgSpec::opt("current-dir", "c", ".", "directory holding the fresh BENCH_*.json reports"),
    ArgSpec::opt("fail-pct", "", "30", "fail the gate above this p50 regression (percent)"),
    ArgSpec::opt("warn-pct", "", "15", "warn above this p50 regression (percent)"),
    ArgSpec::opt("summary", "", "", "append the markdown summary to this file (e.g. $GITHUB_STEP_SUMMARY)"),
    ArgSpec::opt("inflate-current", "", "0", "self-test aid: scale current p50/p95 up by this percent first"),
];

/// `BENCH_*.json` filenames in `dir`, sorted (empty when the directory
/// does not exist).
fn bench_files(dir: &Path) -> Vec<String> {
    let mut out = Vec::new();
    if let Ok(entries) = std::fs::read_dir(dir) {
        for entry in entries.flatten() {
            let name = entry.file_name().to_string_lossy().into_owned();
            if name.starts_with("BENCH_") && name.ends_with(".json") {
                out.push(name);
            }
        }
    }
    out.sort();
    out
}

fn load(path: &Path) -> Result<json::Value, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    json::parse(&text).map_err(|e| format!("parse {}: {e}", path.display()))
}

/// Baseline provenance from `<baseline-dir>/MANIFEST.json` (the
/// io::manifest directory manifest committed next to the baselines):
/// which commit/toolchain produced them and whether quick mode was on.
/// Best-effort — a missing or unparseable manifest returns `None` and
/// never fails the gate.
fn baseline_provenance(baseline_dir: &Path) -> Option<String> {
    let doc = load(&baseline_dir.join("MANIFEST.json")).ok()?;
    let meta = doc.get("meta")?;
    let val = |key: &str| match meta.get(key) {
        Some(json::Value::Str(s)) => s.clone(),
        Some(v) => v.to_string(),
        None => "unknown".to_string(),
    };
    Some(format!(
        "baseline provenance: commit {} | toolchain {} | quick_mode {}\n\n",
        val("commit"),
        val("toolchain"),
        val("quick_mode"),
    ))
}

fn run() -> Result<ExitCode, String> {
    let args = match Args::parse_spec("bench_diff", SPEC, std::env::args().skip(1)) {
        Ok(a) => a,
        Err(CliError::Help) => return Ok(ExitCode::SUCCESS),
        Err(e) => return Err(e.to_string()),
    };
    let baseline_dir = PathBuf::from(args.get("baseline-dir").unwrap());
    let current_dir = PathBuf::from(args.get("current-dir").unwrap());
    let tol = Tolerance {
        warn_pct: args.get_parsed::<f64>("warn-pct").map_err(|e| e.to_string())?,
        fail_pct: args.get_parsed::<f64>("fail-pct").map_err(|e| e.to_string())?,
    };
    let inflate_pct = args.get_parsed::<f64>("inflate-current").map_err(|e| e.to_string())?;

    let current_files = bench_files(&current_dir);
    if current_files.is_empty() {
        return Err(format!(
            "no BENCH_*.json in {} — did the bench run produce reports?",
            current_dir.display()
        ));
    }

    let mut compared: Vec<BenchComparison> = Vec::new();
    let mut md = String::new();
    for file in &current_files {
        let stem = file.trim_end_matches(".json");
        let mut current = load(&current_dir.join(file))?;
        if inflate_pct != 0.0 {
            current = inflate_report(&current, inflate_pct);
        }
        let base_path = baseline_dir.join(file);
        if base_path.is_file() {
            let baseline = load(&base_path)?;
            compared.push(compare(stem, &baseline, &current, tol));
        } else {
            md.push_str(&markdown_current_only(stem, &current));
        }
    }
    // a baseline REPORT with no current counterpart means a whole
    // bench group silently stopped producing numbers (binary deleted,
    // renamed, or crashed before writing) — that is a gate failure,
    // unlike vanished individual cases; intentional removals update
    // bench-history/ in the same PR
    let vanished: Vec<String> = bench_files(&baseline_dir)
        .into_iter()
        .filter(|f| !current_files.contains(f))
        .collect();
    let verdict =
        if vanished.is_empty() { worst(&compared) } else { Verdict::Fail };
    // with nothing compared and nothing vanished, the run is
    // reporting-only: say so in the headline instead of printing a
    // vacuous "perf gate: ok" over zero cases
    let reporting_only = compared.is_empty() && vanished.is_empty();
    let mut summary = if reporting_only {
        markdown_reporting_only(current_files.len(), &baseline_dir.display().to_string())
    } else {
        markdown(&compared, tol, verdict)
    };
    if !vanished.is_empty() {
        summary.push_str(&format!(
            "**FAIL**: baseline reports with no current counterpart (bench group \
             vanished): {}\n\n",
            vanished.join(", ")
        ));
    }
    summary.push_str(&md);
    if let Some(prov) = baseline_provenance(&baseline_dir) {
        summary.push_str(&prov);
    }
    if inflate_pct != 0.0 {
        summary.push_str(&format!(
            "\n(self-test mode: current numbers inflated by {inflate_pct}% before comparing)\n"
        ));
    }
    println!("{summary}");
    if let Some(path) = args.get("summary").filter(|p| !p.is_empty()) {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .map_err(|e| format!("open summary {path}: {e}"))?;
        f.write_all(summary.as_bytes()).map_err(|e| format!("write summary {path}: {e}"))?;
    }
    Ok(if verdict == Verdict::Fail { ExitCode::FAILURE } else { ExitCode::SUCCESS })
}

fn main() -> ExitCode {
    match run() {
        Ok(code) => code,
        Err(e) => {
            eprintln!("bench_diff: {e}");
            // distinct from the gate's FAIL exit so CI logs show
            // infrastructure errors as such
            ExitCode::from(2)
        }
    }
}
