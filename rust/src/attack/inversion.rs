//! Gradient-inversion probe (§4 / §3.1's security argument).
//!
//! For an MLP first layer `z = xW + b` trained with cross-entropy on a
//! single sample, the weight gradient is the rank-1 outer product
//! `∂L/∂W = xᵀδ` and the bias gradient is `δ`. A server holding the
//! *dense* gradient can therefore reconstruct the input exactly:
//! pick any unit j with δ_j ≠ 0 and read off `x = (∂L/∂W)[:, j] / δ_j`
//! — the classic FL leakage the paper cites ([6, 8, 24]).
//!
//! Sparsified uploads break this: only the top-|·| entries of the
//! column survive, so the reconstruction is missing (1−s) of its
//! pixels. [`reconstruction_quality`] quantifies the §3.1 claim
//! ("uploads one percent of the real gradient … the ability of the
//! server to carry out gradient attack will be greatly weakened") as
//! reconstruction cosine-similarity vs sparsity, reported by
//! `examples/secure_agg_demo.rs` and asserted in tests.

/// Reconstruct the input from a (possibly sparsified) first-layer
/// gradient. `grad_w` is `[in_dim × out_dim]` row-major, `grad_b` is
/// `[out_dim]`. Returns None when every bias-gradient entry was
/// sparsified away (no usable column).
pub fn reconstruct_from_dense_grad(
    grad_w: &[f32],
    grad_b: &[f32],
    in_dim: usize,
    out_dim: usize,
) -> Option<Vec<f32>> {
    assert_eq!(grad_w.len(), in_dim * out_dim, "grad_w shape");
    assert_eq!(grad_b.len(), out_dim, "grad_b shape");
    // strongest usable column = largest |δ_j|
    let (j, dj) = grad_b
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.abs().total_cmp(&b.1.abs()))?;
    if *dj == 0.0 {
        return None;
    }
    Some((0..in_dim).map(|i| grad_w[i * out_dim + j] / dj).collect())
}

/// Cosine similarity between the reconstruction and the true input
/// (0 when reconstruction failed).
pub fn reconstruction_quality(recon: Option<&[f32]>, truth: &[f32]) -> f64 {
    let Some(r) = recon else { return 0.0 };
    assert_eq!(r.len(), truth.len());
    let dot: f64 = r.iter().zip(truth).map(|(&a, &b)| a as f64 * b as f64).sum();
    let na: f64 = r.iter().map(|&a| (a as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = truth.iter().map(|&b| (b as f64).powi(2)).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na * nb)
    }
}

/// Attack-vs-sparsity curve: reconstruction quality after flat Top-k
/// sparsification of the gradient at each rate.
#[derive(Clone, Debug)]
pub struct InversionReport {
    pub rates: Vec<f64>,
    pub quality: Vec<f64>,
}

impl InversionReport {
    /// Run the probe over sparsity rates for a synthetic single-sample
    /// gradient built from `input` and logits-gradient `delta`.
    pub fn sweep(input: &[f32], delta: &[f32], rates: &[f64]) -> Self {
        let (in_dim, out_dim) = (input.len(), delta.len());
        // dense rank-1 gradient
        let mut grad = vec![0f32; in_dim * out_dim + out_dim];
        for i in 0..in_dim {
            for j in 0..out_dim {
                grad[i * out_dim + j] = input[i] * delta[j];
            }
        }
        grad[in_dim * out_dim..].copy_from_slice(delta);

        let quality = rates
            .iter()
            .map(|&s| {
                let out = crate::sparse::flat::flat_topk_sparsify(&grad, s);
                let gw = &out.sparse[..in_dim * out_dim];
                let gb = &out.sparse[in_dim * out_dim..];
                let recon = reconstruct_from_dense_grad(gw, gb, in_dim, out_dim);
                reconstruction_quality(recon.as_deref(), input)
            })
            .collect();
        Self { rates: rates.to_vec(), quality }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.next_f32()).collect()
    }

    #[test]
    fn dense_gradient_reconstructs_exactly() {
        let x = sample(1, 64);
        let mut rng = Rng::new(2);
        let delta: Vec<f32> = (0..10).map(|_| rng.normal_f32(0.3)).collect();
        let mut gw = vec![0f32; 64 * 10];
        for i in 0..64 {
            for j in 0..10 {
                gw[i * 10 + j] = x[i] * delta[j];
            }
        }
        let recon = reconstruct_from_dense_grad(&gw, &delta, 64, 10).unwrap();
        let q = reconstruction_quality(Some(&recon), &x);
        assert!(q > 0.999, "q={q}");
        for (a, b) in recon.iter().zip(&x) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn sparsification_degrades_reconstruction() {
        let x = sample(3, 784);
        let mut rng = Rng::new(4);
        let delta: Vec<f32> = (0..10).map(|_| rng.normal_f32(0.3)).collect();
        let report = InversionReport::sweep(&x, &delta, &[1.0, 0.1, 0.01, 0.001]);
        // §3.1: quality must drop monotonically-ish with sparsity
        assert!(report.quality[0] > 0.999, "dense q={}", report.quality[0]);
        assert!(
            report.quality[3] < 0.8 * report.quality[0],
            "s=0.001 q={} not degraded vs dense {}",
            report.quality[3],
            report.quality[0]
        );
        assert!(report.quality[1] >= report.quality[2] - 0.05);
    }

    #[test]
    fn zero_bias_grad_fails_cleanly() {
        let gw = vec![1f32; 8 * 2];
        let gb = vec![0f32; 2];
        assert!(reconstruct_from_dense_grad(&gw, &gb, 8, 2).is_none());
        assert_eq!(reconstruction_quality(None, &[1.0]), 0.0);
    }
}
