//! Gradient-inversion attack harness — empirical support for the
//! paper's §4 safety analysis (DESIGN.md S24).

pub mod inversion;

pub use inversion::{reconstruct_from_dense_grad, reconstruction_quality, InversionReport};
